"""SLO monitor, critical-path analyzer, and root-cause diagnosis
(ISSUE 10 / DESIGN.md §15): opt-in tap with zero threads and
bit-identical results when disabled, multi-window burn-rate alerting in
bus time, phase attribution that reconstructs the makespan, and
symptom-based findings that name injected faults without reading the
injection oracle."""

import numpy as np
import pytest

from repro.platform import (
    MonitorOptions,
    Platform,
    PlatformMonitor,
    PlatformService,
    PlatformSpec,
    SLO,
    MomentsSpec,
    TelemetryBus,
    TelemetryConfig,
)
from repro.platform.monitor import (
    DEFAULT_SLOS,
    SLOPolicy,
    TimeSeriesStore,
    render_monitor_report,
    resolve_monitor_options,
    write_alerts_jsonl,
    write_monitor_report,
)

WL = MomentsSpec(draws=4, draw_size=16)
KNEE = 4 * 96 * 4


def _dataset(n=16, length=96, seed=0):
    rng = np.random.default_rng(seed)
    samples = {i: rng.standard_normal(length).astype(np.float32)
               for i in range(n)}
    months = {i: np.zeros(length, np.int32) for i in range(n)}
    return samples, months


def _spec(**kw):
    base = dict(platform="BTS", n_workers=2, backend="threaded",
                knee_bytes=KNEE, seed=0, max_wave=16)
    base.update(kw)
    return PlatformSpec(**base)


def _results_equal(a, b):
    return (set(a) == set(b)
            and all(np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
                    for k in a))


def _virtual_monitor(**opt_kw):
    bus = TelemetryBus(TelemetryConfig(enabled=True), virtual=True)
    mon = PlatformMonitor(bus, MonitorOptions(enabled=True, **opt_kw),
                          wave_capacity=16)
    return bus, mon


# -- options ------------------------------------------------------------------


def test_resolve_monitor_options_forms():
    assert resolve_monitor_options(None).enabled is False
    assert resolve_monitor_options(False).enabled is False
    assert resolve_monitor_options(True).enabled is True
    assert resolve_monitor_options("on").enabled is True
    opts = MonitorOptions(enabled=True, fast_window=1.0)
    assert resolve_monitor_options(opts) is opts
    with pytest.raises(ValueError):
        resolve_monitor_options("loud")
    with pytest.raises(ValueError):
        MonitorOptions(fast_window=0.0)
    with pytest.raises(ValueError):
        MonitorOptions(history=2)


def test_slo_validation_and_key():
    slo = SLO("queue_depth", 8.0, "above")
    assert slo.key == "queue_depth>8"
    assert slo.violates(9.0) and not slo.violates(8.0)
    below = SLO("hit_ratio", 0.5, "below")
    assert below.key == "hit_ratio<0.5"
    assert below.violates(0.4) and not below.violates(0.6)
    with pytest.raises(ValueError):
        SLO("x", 1.0, "sideways")
    with pytest.raises(ValueError):
        SLO("x", 1.0, burn_threshold=0.0)


# -- time-series store --------------------------------------------------------


def test_store_window_latest_and_bound():
    store = TimeSeriesStore(maxlen=4)
    for ts in range(6):
        store.add("s", float(ts), float(ts * 10))
    assert store.names() == ["s"]
    assert store.latest("s") == (5.0, 50.0)
    # bounded: the first two points fell off
    assert store.window("s", 0.0) == [(2.0, 20.0), (3.0, 30.0),
                                      (4.0, 40.0), (5.0, 50.0)]
    assert store.window("s", 3.0, 4.0) == [(3.0, 30.0), (4.0, 40.0)]
    assert store.window("missing", 0.0) == []
    assert store.latest("missing") is None


def test_store_burn_fraction():
    store = TimeSeriesStore()
    slo = SLO("depth", 5.0, "above")
    assert store.burn_fraction(slo, 0.0, 10.0) is None   # no data
    for ts, v in ((1.0, 9.0), (2.0, 1.0), (3.0, 9.0), (4.0, 9.0)):
        store.add("depth", ts, v)
    assert store.burn_fraction(slo, 0.0, 10.0) == pytest.approx(0.75)
    assert store.burn_fraction(slo, 2.0, 2.5) == pytest.approx(0.0)


# -- multi-window burn-rate policy -------------------------------------------


def test_policy_raise_needs_both_windows():
    store = TimeSeriesStore()
    slo = SLO("depth", 5.0, "above")
    policy = SLOPolicy((slo,), store, fast_window=5.0, slow_window=60.0)
    # a long healthy history, then a short burst: the fast window burns
    # but the slow window does not — no page for a blip
    for ts in range(0, 56):
        store.add("depth", float(ts), 1.0)
    for ts in (56.0, 57.0, 58.0, 59.0, 60.0):
        store.add("depth", ts, 9.0)
    policy.evaluate(60.0)
    assert policy.active() == []
    # sustained burn: violations now dominate both windows
    for ts in range(61, 130):
        store.add("depth", float(ts), 9.0)
    policy.evaluate(129.0)
    active = policy.active()
    assert [a["alert"] for a in active] == ["depth>5"]
    assert active[0]["raised_ts"] == 129.0
    assert active[0]["cleared_ts"] is None


def test_policy_clear_and_history():
    store = TimeSeriesStore()
    slo = SLO("depth", 5.0, "above")
    policy = SLOPolicy((slo,), store, fast_window=5.0, slow_window=60.0)
    for ts in (1.0, 2.0, 3.0):
        store.add("depth", ts, 9.0)
    policy.evaluate(3.0)
    assert policy.active()
    # empty fast window: hold state rather than flap
    policy.evaluate(50.0)
    assert policy.active()
    # recovery fills the fast window with good samples
    for ts in (51.0, 52.0, 53.0):
        store.add("depth", ts, 1.0)
    policy.evaluate(53.0)
    assert policy.active() == []
    (rec,) = policy.history()
    assert rec["raised_ts"] == 3.0
    assert rec["cleared_ts"] == 53.0


def test_policy_emits_alert_events_through_bus():
    bus, mon = _virtual_monitor()
    bus.emit("node_state_change", ts=1.0, node=0, state="down",
             resp_ema=0.1, consecutive_failures=3)
    raised = bus.events("alert_raised")
    assert len(raised) == 1
    assert raised[0].fields["sli"] == "nodes_down"
    assert raised[0].ts == 1.0                  # virtual time
    # recovery: two healthy samples push the fast burn under threshold
    bus.emit("node_state_change", ts=2.0, node=0, state="healthy",
             resp_ema=0.001, consecutive_failures=0)
    bus.emit("node_state_change", ts=7.0, node=0, state="healthy",
             resp_ema=0.001, consecutive_failures=0)
    assert len(bus.events("alert_cleared")) == 1
    assert mon.policy.active() == []
    snap = bus.metrics.snapshot()["counters"]
    assert snap["alerts_raised"] == 1.0
    assert snap["alerts_cleared"] == 1.0
    mon.close()


def test_latency_slo_option_adds_slo():
    bus, mon = _virtual_monitor(latency_slo_seconds=0.25)
    keys = {s.key for s in mon.policy.slos}
    assert {s.key for s in DEFAULT_SLOS} <= keys
    assert "job_latency_p95>0.25" in keys
    mon.close()


# -- SLI derivation -----------------------------------------------------------


def test_slis_from_event_stream():
    bus, mon = _virtual_monitor()
    bus.emit("task_settled", ts=1.0, task_id=0, worker=0, depth=3,
             fetch_seconds=0.01, exec_seconds=0.02)
    bus.emit("wave_dispatched", ts=1.5, wave_size=8, nbytes=1.0,
             task_ids=(0,), seconds=0.01)
    bus.emit("cache_hit", ts=1.6, sample_id=0)
    bus.emit("cache_miss", ts=1.7, sample_id=1)
    bus.emit("ci_snapshot", ts=1.8, value=0.5, ci_low=0.4, ci_high=0.6,
             half_width=0.1, tasks_in=4, confidence=0.95)
    bus.emit("job_done", ts=2.0, makespan=0.5, tasks_executed=1)
    slis = mon.slis()
    assert slis["queue_depth"] == 3.0
    assert slis["wave_occupancy"] == pytest.approx(0.5)    # 8 of 16
    assert slis["cache_hit_ratio"] == pytest.approx(0.5)
    assert slis["ci_half_width"] == pytest.approx(0.1)
    assert slis["job_latency_p50"] is not None
    assert slis["job_latency_p95"] >= slis["job_latency_p50"]
    mon.close()


# -- critical path ------------------------------------------------------------


def test_critical_path_partitions_execute_window():
    bus, mon = _virtual_monitor()
    bus.emit("task_claimed", ts=0.7, task_ids=(0,), worker=0)
    bus.emit("task_settled", ts=2.0, task_id=0, worker=0, depth=1,
             fetch_seconds=0.3, exec_seconds=0.5)
    bus.emit("task_claimed", ts=2.1, task_ids=(1,), worker=1)
    bus.emit("task_settled", ts=4.0, task_id=1, worker=1, depth=0,
             fetch_seconds=0.4, exec_seconds=1.0)
    bus.emit("job_done", ts=4.1, makespan=4.1, tasks_executed=2,
             t_execute=0.0, startup_seconds=0.5, reduce_seconds=0.1)
    (rec,) = mon.critical_path().values()
    ph = rec["phases"]
    # hand-derived: t1's chain charges exec 1.0 / fetch 0.4 / queue 0.5,
    # the t1→t0 gap charges 0.1, t0's chain charges 0.5/0.3/0.5, and the
    # 0.7 s head splits into 0.5 startup + 0.2 queue
    assert ph["exec"] == pytest.approx(1.5)
    assert ph["fetch"] == pytest.approx(0.7)
    assert ph["queue"] == pytest.approx(1.3)
    assert ph["startup"] == pytest.approx(0.5)
    assert ph["reduce"] == pytest.approx(0.1)
    assert rec["phase_sum"] == pytest.approx(rec["makespan"])
    assert [link["task_id"] for link in rec["path"]] == [0, 1]
    assert rec["tasks_settled"] == 2
    # stragglers ranked by fetch+exec
    assert rec["stragglers"][0]["task_id"] == 1
    mon.close()


def test_critical_path_clamps_settle_before_claim():
    bus, mon = _virtual_monitor()
    # claim stamped AFTER the settle (clock skew between emit sites):
    # phases must clamp, never go negative
    bus.emit("task_claimed", ts=5.0, task_ids=(0,), worker=0)
    bus.emit("task_settled", ts=4.0, task_id=0, worker=0, depth=0,
             fetch_seconds=2.0, exec_seconds=3.0)
    bus.emit("job_done", ts=4.1, makespan=4.1, tasks_executed=1,
             t_execute=0.0, startup_seconds=0.0, reduce_seconds=0.0)
    (rec,) = mon.critical_path().values()
    assert all(v >= 0.0 for v in rec["phases"].values())
    assert rec["phase_sum"] == pytest.approx(4.0)   # the [0, settle] window
    mon.close()


def test_critical_path_simulated_backend_reconstructs_makespan():
    samples, months = _dataset()
    p = Platform(_spec(backend="simulated", telemetry=True, monitor=True))
    p.run(samples, months, WL)
    (rec,) = p.monitor_snapshot()["critical_path"].values()
    assert rec["makespan"] > 0
    assert rec["phase_sum"] == pytest.approx(rec["makespan"], rel=0.05)
    mon_phases = rec["phases"]
    assert set(mon_phases) == {"startup", "queue", "fetch", "exec",
                               "reduce"}


# -- diagnosis rules ----------------------------------------------------------


def test_diagnose_clean_monitor_is_empty():
    bus, mon = _virtual_monitor()
    bus.emit("task_claimed", ts=0.1, task_ids=(0,), worker=0)
    bus.emit("task_settled", ts=0.2, task_id=0, worker=0, depth=0,
             fetch_seconds=0.01, exec_seconds=0.01)
    bus.emit("job_done", ts=0.3, makespan=0.3, tasks_executed=1)
    assert mon.diagnose() == []
    mon.close()


def test_diagnose_node_states_and_ranking():
    bus, mon = _virtual_monitor()
    bus.emit("node_state_change", ts=1.0, node=2, state="down",
             resp_ema=0.1, consecutive_failures=3)
    bus.emit("node_state_change", ts=1.1, node=0, state="degraded",
             resp_ema=0.05, consecutive_failures=0)
    bus.emit("worker_crash", ts=1.2, worker=1)
    bus.emit("lease_reclaimed", ts=1.3, n=6, task_ids=(1, 2, 3, 4, 5, 6))
    findings = mon.diagnose()
    kinds = [f["kind"] for f in findings]
    # critical first, then high, then warning
    assert kinds == ["degraded_node", "degraded_node", "worker_churn",
                     "lease_reclaim_storm"]
    assert findings[0]["severity"] == "critical"
    assert findings[0]["node"] == 2 and findings[0]["state"] == "down"
    assert findings[1]["node"] == 0 and findings[1]["state"] == "degraded"
    assert findings[2]["worker"] == 1
    assert findings[3]["evidence"]["leases_reclaimed"] == 6
    mon.close()


def test_diagnose_slow_node_fallback():
    bus, mon = _virtual_monitor()
    # node 0 serves 10x slower than peers but the store never flagged it
    for i in range(3):
        bus.emit("fetch_done", ts=0.1 * i, sample_id=i, node=0, took=0.01)
        bus.emit("fetch_done", ts=0.1 * i, sample_id=i, node=1, took=0.001)
        bus.emit("fetch_done", ts=0.1 * i, sample_id=i, node=2, took=0.001)
    (finding,) = mon.diagnose()
    assert finding["kind"] == "degraded_node"
    assert finding["node"] == 0 and finding["state"] == "slow"
    assert finding["evidence"]["samples"] == 3
    mon.close()


def test_diagnose_slow_node_needs_min_samples_and_excess():
    bus, mon = _virtual_monitor()
    # one sample only (below min_samples), and a microsecond-scale gap
    # (below min_excess) on the other node — neither may fire
    bus.emit("fetch_done", ts=0.1, sample_id=0, node=0, took=0.01)
    bus.emit("fetch_done", ts=0.2, sample_id=1, node=1, took=1e-6)
    bus.emit("fetch_done", ts=0.3, sample_id=2, node=1, took=1e-6)
    bus.emit("fetch_done", ts=0.4, sample_id=3, node=2, took=4e-6)
    bus.emit("fetch_done", ts=0.5, sample_id=4, node=2, took=4e-6)
    findings = [f for f in mon.diagnose() if f.get("state") == "slow"]
    assert findings == []     # node 0 undersampled, node 2's excess ~3 µs
    mon.close()


def test_diagnose_cache_thrash_and_shedding():
    bus, mon = _virtual_monitor()
    for i in range(32):
        bus.emit("cache_miss", ts=0.01 * i, sample_id=i)
    for i in range(16):
        bus.emit("cache_evict", ts=0.5 + 0.01 * i, sample_id=i)
    bus.emit("job_rejected", ts=1.0, job_id=7, tasks_executed=0,
             reason="queue full")
    kinds = {f["kind"] for f in mon.diagnose()}
    assert {"cache_thrash", "admission_shedding"} <= kinds
    mon.close()


# -- platform integration -----------------------------------------------------


def test_disabled_default_no_tap_no_events_bit_identical():
    samples, months = _dataset()
    p_off = Platform(_spec(telemetry=True))
    r_off = p_off.run(samples, months, WL)
    assert p_off.monitor is None
    assert getattr(p_off.telemetry, "_taps") == ()
    kinds = p_off.telemetry.snapshot()["events_by_kind"]
    assert "alert_raised" not in kinds and "alert_cleared" not in kinds
    p_on = Platform(_spec(telemetry=True, monitor=True))
    r_on = p_on.run(samples, months, WL)
    assert p_on.monitor is not None
    assert _results_equal(r_off.result, r_on.result)
    with pytest.raises(RuntimeError):
        p_off.monitor_snapshot()
    with pytest.raises(RuntimeError):
        p_off.write_monitor_report("unused.html")


def test_platform_snapshot_and_report(tmp_path):
    samples, months = _dataset()
    p = Platform(_spec(telemetry=True, monitor=True))
    p.run(samples, months, WL)
    snap = p.monitor_snapshot()
    assert snap["findings"] == []            # clean run
    assert snap["critical_path"]
    assert snap["counters"]["events_seen"] > 0
    path = str(tmp_path / "monitor.html")
    p.write_monitor_report(path, title="unit monitor")
    html = open(path).read()
    assert html.lstrip().lower().startswith("<!doctype html")
    assert "unit monitor" in html
    assert "critical path" in html.lower()
    assert "src=" not in html and "href=" not in html   # self-contained


def test_service_monitor_snapshot_and_artifacts(tmp_path):
    samples, months = _dataset()
    spec = _spec(telemetry=True, monitor=True, n_workers=2)
    with PlatformService(spec) as svc:
        h = svc.register_dataset(samples, months)
        tickets = [svc.submit(h, WL, seed=s) for s in (1, 2)]
        for t in tickets:
            t.result(timeout=300)
        snap = svc.monitor_snapshot()
        report_path = str(tmp_path / "svc_monitor.html")
        svc.write_monitor_report(report_path)
        alerts_path = str(tmp_path / "alerts.jsonl")
        n_alerts = write_alerts_jsonl(svc.monitor, alerts_path)
    assert snap["findings"] == []
    # one critical path per submitted job
    job_ids = {t.job_id for t in tickets}
    assert job_ids <= set(snap["critical_path"])
    for jid in job_ids:
        rec = snap["critical_path"][jid]
        assert rec["phase_sum"] > 0
        assert rec["tasks_settled"] > 0
    html = open(report_path).read()
    assert "none — clean run" in html
    assert n_alerts == len(snap["alerts"]["history"])


def test_service_monitor_disabled_raises():
    samples, months = _dataset()
    with PlatformService(_spec(telemetry=True)) as svc:
        assert svc.monitor is None
        with pytest.raises(RuntimeError):
            svc.monitor_snapshot()
        with pytest.raises(RuntimeError):
            svc.write_monitor_report("unused.html")


def test_render_report_with_alerts_and_faults():
    bus, mon = _virtual_monitor()
    bus.emit("node_state_change", ts=1.0, node=1, state="down",
             resp_ema=0.2, consecutive_failures=3)
    bus.emit("task_claimed", ts=1.1, task_ids=(0,), worker=0)
    bus.emit("task_settled", ts=1.5, task_id=0, worker=0, depth=0,
             fetch_seconds=0.1, exec_seconds=0.2)
    bus.emit("job_done", ts=1.6, makespan=1.6, tasks_executed=1,
             t_execute=0.0, startup_seconds=0.0, reduce_seconds=0.0)
    html = render_monitor_report(mon, title="alerting run")
    assert "alerting run" in html
    assert "nodes_down" in html
    assert "DOWN" in html                     # the finding summary
    mon.close()


def test_monitor_close_detaches_tap():
    bus, mon = _virtual_monitor()
    bus.emit("worker_crash", ts=0.5, worker=0)
    assert mon.diagnose()
    mon.close()
    mon.close()                               # idempotent
    assert getattr(bus, "_taps") == ()
    before = mon.snapshot()["counters"]["events_seen"]
    bus.emit("worker_crash", ts=0.6, worker=1)
    assert mon.snapshot()["counters"]["events_seen"] == before
