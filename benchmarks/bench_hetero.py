"""Fig 14/15 — heterogeneity and virtualization.

Thesis: one slow node (12 of 60 cores 15% slower) causes proportional
slowdown on MB-scale jobs but is erased on large jobs (round-robin skips
busy cores; tiny tasks enable stealing); Netflix scales linearly on the
virtualized Type-3 nodes.
"""

from __future__ import annotations

from typing import List

from benchmarks.common import Row, measured_task_cost
from repro.core import scheduler as sch
from repro.core import subsample as ss
from repro.core.tiny_task import make_tasks
from repro.data.synthetic import NetflixSpec, netflix_dataset

SAMPLE_BYTES = 2048 * 4


def _makespan(workers, n_samples, per_sample) -> float:
    sizes = [SAMPLE_BYTES] * n_samples
    tasks = make_tasks(sizes, "kneepoint", 8 * SAMPLE_BYTES, len(workers))
    params = sch.SimParams(
        exec_time=lambda t: len(t.sample_ids) * per_sample,
        fetch_time=lambda t: 1e-4, launch_overhead=5e-4,
        startup_time=0.05)
    return sch.simulate_job(tasks, workers, params).makespan


def run() -> List[Row]:
    rows: List[Row] = []
    samples, months = netflix_dataset(NetflixSpec(n_movies=32,
                                                  mean_ratings=2048))
    per_sample = measured_task_cost(samples, months, ss.NETFLIX_HIGH)

    uniform = [sch.SimWorker(i) for i in range(20)]
    hetero = [sch.SimWorker(i, speed=0.85 if i < 4 else 1.0)
              for i in range(20)]
    # small job ≈ one task per worker (straggler-bound, proportional
    # slowdown); large job lets round-robin + stealing erase it
    for n, tag in ((160, "small_job"), (4096, "large_job")):
        t_u = _makespan(uniform, n, per_sample)
        t_h = _makespan(hetero, n, per_sample)
        rows.append((f"hetero.{tag}.slowdown", 0.0,
                     f"{t_h / t_u:.3f}x_(1.0=erased;cap_loss=3%)"))

    tp12 = None
    for cores in (12, 24, 48):
        workers = [sch.SimWorker(i, speed=0.84) for i in range(cores)]
        t = _makespan(workers, 4096, per_sample)
        tp = 4096 * SAMPLE_BYTES / t
        if cores == 12:
            tp12 = tp
        rows.append((f"hetero.virt_{cores}cores.bytes_per_s", tp,
                     f"scaling_vs_12={tp / tp12 / (cores / 12):.2f}"))
    return rows
