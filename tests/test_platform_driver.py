"""End-to-end tests for the ``repro.platform`` driver: backend parity
(bit-identical statistics), report structure, kneepoint working-set
bounds, the streaming reduce tree, and engine fallbacks."""

import numpy as np
import pytest

from repro.core import subsample as ss
from repro.core.datastore import ReplicatedDataStore, ReplicationPolicy
from repro.core.scheduler import SimWorker
from repro.platform import (
    MOMENTS,
    Platform,
    PlatformSpec,
    StreamingReduceTree,
    finalize_stats,
    make_tasks,
    measure_per_sample_cost,
)
from repro.data.synthetic import (
    EagletSpec,
    NetflixSpec,
    eaglet_dataset,
    netflix_dataset,
)

KNEE = 4 * 1024 * 4


@pytest.fixture(scope="module")
def netflix():
    return netflix_dataset(NetflixSpec(n_movies=24, mean_ratings=1024))


# -- backend parity -----------------------------------------------------------

@pytest.mark.parametrize("workload", [ss.NETFLIX_HIGH, MOMENTS],
                         ids=["monthly_mean", "moments"])
def test_threaded_and_simulated_backends_bit_identical(netflix, workload):
    """Same seed + same engine + deterministic reduce tree ⇒ the two
    backends must agree to the last bit, at different worker counts."""
    samples, months = netflix
    threaded = Platform(PlatformSpec(
        platform="BTS", n_workers=3, backend="threaded",
        knee_bytes=KNEE, seed=11)).run(samples, months, workload)
    simulated = Platform(PlatformSpec(
        platform="BTS", n_workers=7, backend="simulated",
        knee_bytes=KNEE, seed=11)).run(samples, months, workload)
    assert threaded.result is not None and simulated.result is not None
    for key in threaded.result:
        np.testing.assert_array_equal(
            np.asarray(threaded.result[key]),
            np.asarray(simulated.result[key]),
            err_msg=f"backends diverged on {key!r}")


def test_simulated_backend_with_heterogeneous_workers_same_stats(netflix):
    samples, months = netflix
    base = Platform(PlatformSpec(
        platform="BTS", n_workers=2, backend="threaded",
        knee_bytes=KNEE, seed=5)).run(samples, months, ss.NETFLIX_HIGH)
    hetero = Platform(PlatformSpec(
        platform="BTS", backend="simulated", knee_bytes=KNEE, seed=5,
        sim_workers=tuple(SimWorker(i, speed=1.0 if i % 2 else 0.5)
                          for i in range(6)))).run(samples, months,
                                                   ss.NETFLIX_HIGH)
    np.testing.assert_array_equal(base.result["monthly_mean"],
                                  hetero.result["monthly_mean"])
    assert hetero.makespan > 0


# -- report structure ---------------------------------------------------------

def test_job_report_phases_populated(netflix):
    samples, months = netflix
    rep = Platform(PlatformSpec(
        platform="BTS", n_workers=2, backend="threaded",
        knee_bytes=KNEE)).run(samples, months, ss.NETFLIX_HIGH)
    for phase in ("plan", "distribute", "compile", "execute", "reduce"):
        assert phase in rep.phases, rep.phases
        assert rep.phases[phase] >= 0.0
    # execute must dominate a knee-supplied job and include startup
    assert rep.phases["execute"] > 0
    assert rep.makespan >= rep.startup_time
    assert rep.queue_depths, "dynamic-k trace missing"
    assert rep.reduce_info is not None and rep.reduce_info["combines"] >= 0
    assert rep.backend == "threaded" and rep.engine == "jnp"
    assert rep.throughput_bps > 0


def test_offline_kneepoint_phase_charged_and_curve_reported(netflix):
    samples, months = netflix
    rep = Platform(PlatformSpec(
        platform="BTS", n_workers=2, backend="threaded",
        kneepoint_sizes=(1, 2, 4, 8))).run(samples, months,
                                           ss.NETFLIX_HIGH)
    assert rep.kneepoint is not None
    assert rep.phases["plan"] > 0            # offline phase actually ran
    assert len(rep.miss_curve) >= 2          # cache-proxy miss curve
    assert rep.task_size_bytes == rep.kneepoint.task_size


def test_kneepoint_task_size_bounds_working_set():
    """Every task's working set must stay within the knee (plus one mean
    sample of count-rounding slack)."""
    sample_bytes = 512 * 4
    samples = {i: np.zeros(512, np.float32) for i in range(64)}
    months = {i: np.zeros(512, np.int32) for i in range(64)}
    knee = 8 * sample_bytes
    rep = Platform(PlatformSpec(
        platform="BTS", n_workers=2, backend="simulated",
        knee_bytes=knee)).run(samples, months, ss.NETFLIX_LOW)
    assert rep.max_task_bytes <= knee + sample_bytes
    assert rep.n_tasks == 8                  # 64 samples / 8 per task


def test_make_tasks_partitions_every_sizing():
    sizes = [100] * 37
    for sizing, knee in (("tiny", None), ("large", None),
                         ("kneepoint", 400)):
        tasks = make_tasks(sizes, sizing, knee, 4)
        flat = sorted(i for t in tasks for i in t.sample_ids)
        assert flat == list(range(37)), sizing


# -- datastore integration ----------------------------------------------------

def test_datastore_feedback_and_stats_in_report(netflix):
    samples, months = netflix
    store = ReplicatedDataStore(
        n_initial=1, policy=ReplicationPolicy(fetch_slo=2e-3))
    rep = Platform(PlatformSpec(
        platform="BTS", n_workers=2, backend="threaded",
        knee_bytes=KNEE), datastore=store).run(samples, months,
                                               ss.NETFLIX_HIGH)
    assert rep.datastore_stats is not None
    assert rep.datastore_stats["replicas"] >= 1
    assert store._exec_ema is not None       # scheduler feedback arrived


# -- scale-out entry ----------------------------------------------------------

def test_run_scaleout_throughput_scales_with_workers():
    per_sample = 2e-4
    tp = {}
    for cores in (4, 16):
        rep = Platform(PlatformSpec(
            platform="BTS", n_workers=cores, backend="simulated",
            knee_bytes=8 * 2048,
            startup_time=0.005)).run_scaleout(   # large-job linear region
                [2048] * 2048, per_sample_exec=per_sample)
        assert rep.result is None            # cost-model mode: no stats
        tp[cores] = rep.throughput_bps
    assert tp[16] > 2.5 * tp[4]


# -- reduce tree --------------------------------------------------------------

def test_reduce_tree_order_independent_and_exact():
    rng = np.random.default_rng(0)
    parts = [{"sum": rng.normal(size=16).astype(np.float32),
              "count": np.float32(1)} for _ in range(13)]

    def run_order(order):
        tree = StreamingReduceTree(len(parts))
        for i in order:
            tree.offer(i, parts[i])
        return tree.result(timeout=30)

    a = run_order(range(13))
    b = run_order(reversed(range(13)))
    c = run_order(np.random.default_rng(3).permutation(13))
    np.testing.assert_array_equal(a["sum"], b["sum"])
    np.testing.assert_array_equal(a["sum"], c["sum"])
    assert a["count"] == 13


def test_finalize_stats_moments():
    root = {"sum": np.asarray([10.0, 0.0]), "sumsq": np.asarray([30.0, 4.0]),
            "count": np.asarray(10.0)}
    out = finalize_stats(root, "moments")
    np.testing.assert_allclose(out["mean"], [1.0, 0.0])
    np.testing.assert_allclose(out["var"], [2.0, 0.4])


# -- engines ------------------------------------------------------------------

def test_numpy_engine_statistically_matches_jnp(netflix):
    samples, months = netflix
    spec = dict(platform="BTS", n_workers=2, backend="threaded",
                knee_bytes=KNEE, seed=0)
    jnp_rep = Platform(PlatformSpec(engine="jnp", **spec)).run(
        samples, months, ss.NETFLIX_HIGH)
    np_rep = Platform(PlatformSpec(engine="numpy", **spec)).run(
        samples, months, ss.NETFLIX_HIGH)
    a, b = jnp_rep.result["monthly_mean"], np_rep.result["monthly_mean"]
    valid = (np.asarray(jnp_rep.result["count"]) > 50) \
        & (np.asarray(np_rep.result["count"]) > 50)
    assert valid.sum() > 10
    assert np.mean(np.abs(a[valid] - b[valid])) < 0.25


def test_custom_map_fn_with_overhead_config():
    samples = {i: np.zeros(8, np.float32) for i in range(10)}
    months = {i: np.zeros(8, np.int32) for i in range(10)}
    calls = []

    def map_fn(task, block, mo, seed):
        calls.append(task.task_id)
        return {"count": np.asarray(1.0, np.float32)}

    rep = Platform(PlatformSpec(platform="VH", n_workers=1,
                                backend="threaded", task_sizing="tiny"),
                   map_fn=map_fn).run(samples, months, None)
    assert sorted(calls) == list(range(10))
    assert rep.n_tasks == 10
    assert rep.result["count"] == 10.0
    assert rep.engine == "custom"


# -- eaglet end-to-end through the driver -------------------------------------

def test_eaglet_outliers_run_end_to_end():
    samples, months = eaglet_dataset(EagletSpec(n_families=24,
                                                mean_markers=512,
                                                heavy_tail=True))
    rep = Platform(PlatformSpec(
        platform="BTS", n_workers=2, backend="simulated",
        knee_bytes=8 * 512 * 4, seed=1)).run(samples, months, ss.EAGLET)
    assert np.all(np.isfinite(rep.result["alod"]))
    assert rep.calibration_seconds > 0


def test_measure_per_sample_cost_positive(netflix):
    samples, months = netflix
    cost = measure_per_sample_cost(samples, months, ss.NETFLIX_LOW,
                                   block=4)
    assert 0 < cost < 1.0
