"""Fig 2 — task size → cost curve and kneepoints (EAGLET + Netflix).

The thesis measured L2 misses/instruction with OProfile; here the proxy is
wall time per sample (plus the AMAT model for reference).  The deliverable
is the curve shape: flat, then sharp growth past the knee; the kneepoint
detector must land before the growth region.
"""

from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core import subsample as ss
from repro.core.kneepoint import amat_curve, find_kneepoint
from repro.core.tiny_task import measure_kneepoint
from repro.data.synthetic import (EagletSpec, NetflixSpec, eaglet_dataset,
                                  netflix_dataset)


def run() -> List[Row]:
    rows: List[Row] = []
    # 32k-marker samples put multi-sample blocks at MB scale, where the
    # draw-major random gather shows the measured cache knee (per-row cost
    # floor at ~1–4 MiB, ≈1.6× growth past ~8 MiB on this node)
    samples, months = eaglet_dataset(EagletSpec(n_families=128,
                                                mean_markers=32768,
                                                heavy_tail=False))
    res, knee = measure_kneepoint(samples, months, ss.EAGLET,
                                  sizes=(1, 2, 4, 8, 16, 32, 64, 128))
    for p in res.curve:
        rows.append((f"kneepoint.eaglet.curve.{int(p.task_size)}B",
                     p.cost * 1e6, "us_per_sample"))
    rows.append(("kneepoint.eaglet.knee_bytes", knee,
                 f"idx={res.index};{res.reason[:40]}"))

    nsamples, nmonths = netflix_dataset(NetflixSpec(n_movies=96,
                                                    mean_ratings=16384))
    for wl in (ss.NETFLIX_HIGH, ss.NETFLIX_LOW):
        res, knee = measure_kneepoint(nsamples, nmonths, wl,
                                      sizes=(1, 2, 4, 8, 16, 32, 64))
        rows.append((f"kneepoint.{wl.name}.knee_bytes", knee,
                     f"idx={res.index}"))

    # AMAT reference model on the thesis' Sandy Bridge hierarchy: knees
    # must appear at cache-capacity scale (thesis: 2.5MB and 11MB)
    ws = np.geomspace(2**18, 2**26, 24)
    amat = find_kneepoint(amat_curve(ws), tolerance=0.3)
    rows.append(("kneepoint.amat_model.knee_bytes", amat.task_size,
                 "sandy_bridge_hierarchy"))
    return rows
