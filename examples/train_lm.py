"""End-to-end training driver: a ~100M-parameter dense LM trained for a
few hundred steps on CPU, using every layer of the framework — the
subsampling input pipeline (kneepoint-sized prefetch), microbatch tiny
tasks, sharded AdamW, job-level checkpointing, and resume-after-restart.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import logging


from repro.checkpoint import CheckpointManager
from repro.config import ModelConfig, RunConfig, ShapeConfig, TrainConfig
from repro.config.base import MeshConfig
from repro.data import PipelineConfig, SubsamplingBatchPipeline, lm_token_corpus
from repro.data.pipeline import tune_microbatch_tokens
from repro.models import build_model
from repro.train import train

logging.basicConfig(level=logging.INFO, format="%(message)s")


def make_100m_config() -> ModelConfig:
    return ModelConfig(
        name="demo-100m", family="dense",
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32768,
        rope_theta=10_000.0,
        microbatch_tokens_per_device=tune_microbatch_tokens(
            seq_len=256, d_model=512, num_layers=8),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = make_100m_config()
    model = build_model(cfg)
    n_params = cfg.param_count()
    print(f"model: {n_params / 1e6:.0f}M params "
          f"(microbatch kneepoint: {cfg.microbatch_tokens_per_device} "
          f"tokens/device)")

    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("train", "train", args.seq, args.batch),
        mesh=MeshConfig((1, 1), ("data", "model")),
        train=TrainConfig(learning_rate=3e-4, warmup_steps=20,
                          total_steps=args.steps))

    corpus = lm_token_corpus(1 << 20, cfg.vocab_size)
    pipe = SubsamplingBatchPipeline(
        corpus, PipelineConfig(batch_size=args.batch, seq_len=args.seq))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    report = train(model, run, pipe.batches(None), num_steps=args.steps,
                   checkpoint_manager=mgr, checkpoint_every=100,
                   log_every=20)
    first = report.losses[0] if report.losses else float("nan")
    print(f"\ntrained {report.steps} steps in {report.seconds:.1f}s "
          f"({args.batch * args.seq * len(report.losses) / report.seconds:.0f}"
          f" tok/s)")
    print(f"loss: {first:.3f} → {report.final_loss:.3f}")
    print(f"checkpoints: {mgr.all_steps()} in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
