"""Back-compat facade over :mod:`repro.platform` (thesis §4.1.3 configs).

The end-to-end tiny-task pipeline — kneepoint sizing, task partitioning,
scheduling, datastore fetch, streaming reduce — now lives in
``repro.platform`` (the Platform driver).  This module keeps the original
entry points (``PLATFORMS``, ``make_tasks``, ``run_subsampling_job``,
``measure_kneepoint``) so existing callers and tests keep working; new
code should use :class:`repro.platform.Platform` directly.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.platform.compute import pad_to_common
from repro.platform.driver import (  # noqa: F401  (re-exported API)
    BASH_STARTUP,
    PLATFORMS,
    JobReport,
    Platform,
    PlatformConfig,
    PlatformSpec,
    make_tasks,
    measure_kneepoint,
)


def run_subsampling_job(
    samples: Dict[int, np.ndarray],
    months: Dict[int, np.ndarray],
    workload,
    *,
    platform: str = "BTS",
    n_workers: int = 4,
    knee_bytes: Optional[float] = None,
    datastore=None,
    seed: int = 0,
) -> JobReport:
    """Execute a subsampling job on the threaded backend (real wall time).

    Thin wrapper over :class:`repro.platform.Platform`; the offline
    kneepoint phase, if needed and not supplied, runs first and is charged
    to the report (thesis accounting: offline ≈ 3% of online).
    """
    spec = PlatformSpec(platform=platform, n_workers=n_workers,
                        backend="threaded", knee_bytes=knee_bytes,
                        seed=seed)
    return Platform(spec, datastore=datastore).run(samples, months, workload)


def _pad_to_common(arrays: List[np.ndarray]) -> List[np.ndarray]:
    """Deprecated alias — moved to ``repro.platform.compute.pad_to_common``."""
    return pad_to_common(arrays)
