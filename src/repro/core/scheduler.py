"""Two-phase dynamic scheduler for tiny tasks (thesis §1.1.2, §3.4, Fig 7).

Phase 1 (probe): exactly one task is assigned to each worker; their
fetch/execution times seed the feedback loop.

Phase 2 (batched queues): the feedback loop assigns *batches* of tasks to
per-worker queues so a worker never waits between millisecond tasks; the
queue look-ahead ``k`` is set dynamically from the measured ratio of data
fetch time to task execution time (the prefetch window of §3.5).  Straggler
mitigation: round-robin refill that skips busy/slow workers, power-of-two
shortest-queue choice, and work stealing from the deepest queue when a
worker idles (thesis §4.2.4).

Fault model (thesis §3.3): job-level recovery — a worker failure aborts and
restarts the *whole job* (`JobFailure`), which the driver retries; optional
task-level mode re-queues the failed task but charges every task the
monitoring overhead ``cost_tl``.

Two drivers share this policy object:
  * :func:`simulate_job` — single-threaded discrete-event simulation with
    virtual time (used for scale-out/elasticity/heterogeneity benchmarks:
    this container has one physical core, so >1-worker wall-clock
    parallelism must be simulated; per-task durations are *measured* on the
    real workload first).
  * :class:`ThreadedRunner` — real threads + queues, real wall time (used
    for overhead microbenchmarks and the runnable examples).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import recovery as rec


@dataclasses.dataclass
class Task:
    task_id: int
    sample_ids: Tuple[int, ...]
    size_bytes: float
    payload: Any = None


@dataclasses.dataclass
class TaskResult:
    task_id: int
    worker_id: int
    start: float
    fetch_time: float
    exec_time: float
    value: Any = None


def rank_by_bucket(tasks: Sequence[Task],
                   key_fn: Callable[[Task], Any],
                   score_fn: Callable[[Task], float]) -> "deque[Task]":
    """Stable-sort tasks by each bucket's best locality score: whole
    buckets move together (same-shape waves / cross-job fusion keys
    stay contiguous), intra-bucket order stays FIFO, and ties keep
    arrival order.  Shared by both schedulers' claim ranking.

    The score is the driver's ``locality_score`` — predicted best-
    replica fetch seconds, with cache-resident tasks scoring ~0
    (DESIGN.md §14), so buckets whose blocks the pool already holds
    drain first and a cache admission/eviction re-ranks via
    ``request_rerank`` exactly like a node state change."""
    tasks = list(tasks)
    if len(tasks) <= 1:
        return deque(tasks)
    score: Dict[Any, float] = {}
    first_seen: Dict[Any, int] = {}
    for i, t in enumerate(tasks):
        b = key_fn(t)
        s = float(score_fn(t))
        if b not in score or s < score[b]:
            score[b] = s
        first_seen.setdefault(b, i)
    tasks.sort(key=lambda t: (score[key_fn(t)], first_seen[key_fn(t)]))
    return deque(tasks)


class JobFailure(RuntimeError):
    """Raised when a worker dies under job-level recovery; the driver
    restarts the entire job (thesis §3.3)."""

    def __init__(self, msg: str, failed_worker: Optional[int] = None):
        super().__init__(msg)
        self.failed_worker = failed_worker


@dataclasses.dataclass
class SchedulerConfig:
    initial_batch: int = 1            # phase-1 probe tasks per worker
    min_queue_depth: int = 2
    max_queue_depth: int = 64
    power_of_two: bool = True         # two-choice shortest-queue refill
    work_stealing: bool = True
    recovery: str = "job"             # "job" | "task"
    cost_tl: float = 0.20             # task-level monitoring slowdown (Fig 6)
    # speculative re-execution of stragglers: when the backlog is empty,
    # idle workers clone in-flight tasks whose age exceeds
    # ``straggler_factor ×`` the execution-time EMA.  ``False`` off,
    # ``True`` the bare age rule, ``"auto"`` additionally requires the
    # clone to be worth its standing tax per the §3.3 cost model
    # (:func:`repro.core.recovery.should_speculate`).  First completion
    # wins; per-task seeds keep clone results bit-identical.
    speculative: Any = False               # False | True | "auto"
    speculative_factor: float = 2.0        # legacy name for the age factor
    straggler_factor: Optional[float] = None   # overrides when set
    seed: int = 0
    # lease-based task reclamation (DESIGN.md §12): a claimed task whose
    # lease expires is requeued for another worker — the safety net for
    # workers that die without reporting.  First-completion-wins dedup
    # keeps a late original settlement harmless (at-most-once, results
    # bit-identical).  None disables leasing entirely.
    lease_seconds: Optional[float] = None

    def effective_straggler_factor(self) -> float:
        return (self.straggler_factor if self.straggler_factor is not None
                else self.speculative_factor)


class TwoPhaseScheduler:
    """Pure scheduling policy — no clock, no threads.  Drivers call
    :meth:`on_worker_idle` / :meth:`on_task_complete` and execute whatever
    assignments come back.

    ``locality_score(task)`` — when provided — is the predicted fetch
    latency of the task's best available data-node replica (the
    datastore's :meth:`~repro.core.datastore.ReplicatedDataStore.
    predicted_task_fetch`); ready tasks are ranked so workers drain
    cheap-data tasks first, at whole ``bucket_key`` granularity so
    same-shape wave fusion survives the reordering.  The ranking is
    recomputed lazily after :meth:`request_rerank` (wired to the
    datastore's node state-change callback), under whatever lock the
    driver already holds for scheduler calls."""

    def __init__(self, n_workers: int, tasks: Sequence[Task],
                 cfg: SchedulerConfig = SchedulerConfig(), *,
                 locality_score: Optional[Callable[[Task], float]] = None,
                 bucket_key: Optional[Callable[[Task], Any]] = None,
                 telemetry=None):
        self.cfg = cfg
        self.n_workers = n_workers
        self.backlog: deque[Task] = deque(tasks)
        self.queues: List[deque[Task]] = [deque() for _ in range(n_workers)]
        self.inflight: Dict[int, Task] = {}
        self.inflight_by_worker: Dict[int, Task] = {}
        # EVERY claimed-but-unsettled task per worker (a wave claim is
        # many tasks) — what crash/lease reclamation recovers.  The
        # single-task ``inflight_by_worker`` keeps its legacy straggler
        # semantics alongside.
        self.claims_by_worker: Dict[int, Dict[int, Task]] = {}
        self._lease: Dict[int, float] = {}   # task_id -> lease expiry
        self._started_at: Dict[int, float] = {}
        self._first_worker: Dict[int, int] = {}
        self._speculated: set = set()
        self._completed: set = set()
        self.speculative_launches = 0
        self.speculation_wins = 0          # clone finished before original
        self.cancelled_tasks = 0           # dropped by cancel_pending()
        self.worker_crashes = 0            # crashed workers reclaimed
        self.reclaimed_tasks = 0           # tasks requeued by crash/lease
        self.lost_tasks = 0                # dropped permanently (degraded)
        self.results: List[TaskResult] = []
        self.depth_trace: List[int] = []   # dynamic-k after each completion
        # one aggregation path (DESIGN.md §13): the bus's aggregator owns
        # the depth_trace appends; a scheduler built without a bus gets a
        # fresh disabled one (aggregation still runs, ring stays empty)
        if telemetry is None:
            from repro.platform.telemetry import null_bus
            telemetry = null_bus()
        self.telemetry = telemetry
        telemetry.bind_depths(self.depth_trace)
        self.avg_exec = None
        self.avg_fetch = None
        self._rng = np.random.default_rng(cfg.seed)
        self._phase2 = False
        self._alive = [True] * n_workers
        self.locality_score = locality_score
        self.bucket_key = bucket_key or (lambda t: len(t.sample_ids))
        self._rank_dirty = False
        self.reranks = 0
        if locality_score is not None:
            self._rank_backlog()

    # -- response-time-aware claim ordering ----------------------------------
    def request_rerank(self) -> None:
        """Mark the ready ranking stale (safe from any thread — the
        re-sort itself happens inside the next scheduler call, under the
        driver's lock)."""
        self._rank_dirty = True

    def _maybe_rerank(self) -> None:
        if self._rank_dirty:
            self._rank_dirty = False
            self._rank_backlog()

    def _rank_backlog(self) -> None:
        if self.locality_score is None or len(self.backlog) <= 1:
            return
        self.backlog = rank_by_bucket(self.backlog, self.bucket_key,
                                      self.locality_score)
        self.reranks += 1

    # -- feedback loop -------------------------------------------------------
    def _observe(self, result: TaskResult) -> None:
        a = 0.3
        self.avg_exec = (result.exec_time if self.avg_exec is None
                         else (1 - a) * self.avg_exec + a * result.exec_time)
        self.avg_fetch = (result.fetch_time if self.avg_fetch is None
                          else (1 - a) * self.avg_fetch + a * result.fetch_time)

    def queue_depth(self) -> int:
        """Dynamic look-ahead k: enough queued work to cover data fetch
        latency (k ≈ fetch/exec + 1), clamped (thesis §3.5)."""
        if not self.avg_exec:
            return self.cfg.min_queue_depth
        k = int(np.ceil((self.avg_fetch or 0.0) / max(self.avg_exec, 1e-9))) + 1
        return int(np.clip(k, self.cfg.min_queue_depth,
                           self.cfg.max_queue_depth))

    # -- assignment ----------------------------------------------------------
    def initial_assignments(self) -> List[Tuple[int, Task]]:
        """Phase 1: one probe task per worker (random order)."""
        order = self._rng.permutation(self.n_workers)
        out = []
        for w in order:
            for _ in range(self.cfg.initial_batch):
                if self.backlog:
                    t = self.backlog.popleft()
                    self.queues[w].append(t)
                    out.append((int(w), t))
        return out

    def _pick_worker_for_refill(self, preferred: int) -> int:
        if not self.cfg.power_of_two:
            return preferred
        other = int(self._rng.integers(self.n_workers))
        if not self._alive[other]:
            return preferred
        return (other if len(self.queues[other]) < len(self.queues[preferred])
                else preferred)

    def on_task_start(self, worker: int, task: Task,
                      now: Optional[float] = None) -> None:
        self.inflight[task.task_id] = task
        self.inflight_by_worker[worker] = task
        self.claims_by_worker.setdefault(worker, {})[task.task_id] = task
        t_now = time.perf_counter() if now is None else now
        if self.cfg.lease_seconds is not None:
            self._lease[task.task_id] = t_now + self.cfg.lease_seconds
        self._first_worker.setdefault(task.task_id, worker)
        # a speculative clone's start must not reset the straggler clock
        if task.task_id not in self._started_at:
            self._started_at[task.task_id] = t_now

    def on_task_complete(self, result: TaskResult,
                         ts: Optional[float] = None
                         ) -> List[Tuple[int, Task]]:
        """Record a result; return new (worker, task) queue assignments.
        First completion wins — a speculative duplicate's second
        completion is ignored (per-task seeds make both bit-identical).
        ``ts`` stamps the settle event in virtual time (simulated
        backend); wall-time drivers leave it unset."""
        self.inflight_by_worker.pop(result.worker_id, None)
        self.claims_by_worker.get(result.worker_id, {}).pop(
            result.task_id, None)
        if result.task_id in self._completed:
            return []
        self._completed.add(result.task_id)
        if (result.task_id in self._speculated
                and self._first_worker.get(result.task_id)
                != result.worker_id):
            self.speculation_wins += 1     # the clone beat the original
        self.inflight.pop(result.task_id, None)
        self._lease.pop(result.task_id, None)
        self._started_at.pop(result.task_id, None)
        self.results.append(result)
        self._observe(result)
        self._phase2 = True
        self._maybe_rerank()
        w = result.worker_id
        out: List[Tuple[int, Task]] = []
        depth = self.queue_depth()
        # the aggregation path appends ``depth`` to self.depth_trace
        self.telemetry.emit(
            "task_settled", ts=ts, task_id=result.task_id,
            worker=result.worker_id, depth=depth,
            fetch_seconds=result.fetch_time,
            exec_seconds=result.exec_time)
        # batched refill: top this worker's queue up to k (two-choice may
        # divert some of the batch to a shorter queue)
        while self.backlog and len(self.queues[w]) < depth:
            target = self._pick_worker_for_refill(w)
            t = self.backlog.popleft()
            self.queues[target].append(t)
            out.append((target, t))
        return out

    def on_worker_idle(self, worker: int,
                       now: Optional[float] = None) -> Optional[Task]:
        """Next task for an idle worker: its own queue, then the backlog,
        then stealing from the deepest queue, then (optionally) a
        speculative re-execution of the longest-running straggler."""
        if not self._alive[worker]:
            return None
        self._maybe_rerank()
        # lease-reclaimed duplicates: a requeued copy whose original
        # settled in the meantime is dropped at claim time, not run again
        q = self.queues[worker]
        while q:
            t = q.popleft()
            if t.task_id not in self._completed:
                return t
        while self.backlog:
            t = self.backlog.popleft()
            if t.task_id not in self._completed:
                return t
        if self.cfg.work_stealing:
            victim = max(range(self.n_workers),
                         key=lambda i: len(self.queues[i]))
            while len(self.queues[victim]) > 1:
                t = self.queues[victim].pop()      # steal from the tail
                if t.task_id not in self._completed:
                    return t
        if self.cfg.speculative and self.avg_exec and self._started_at:
            t_now = time.perf_counter() if now is None else now
            factor = self.cfg.effective_straggler_factor()
            threshold = factor * self.avg_exec
            candidates = [(t_now - started, tid) for tid, started
                          in self._started_at.items()
                          if tid not in self._speculated
                          and self.inflight_by_worker.get(worker, None)
                          is not self.inflight.get(tid)]
            candidates = [(age, tid) for age, tid in candidates
                          if age > threshold]
            if self.cfg.speculative == "auto":
                # §3.3 economics per clone: worth it only when the
                # expected saving beats the clone's standing tax
                candidates = [
                    (age, tid) for age, tid in candidates
                    if rec.should_speculate(age, self.avg_exec,
                                            straggler_factor=factor)]
            if candidates:
                _, tid = max(candidates)
                self._speculated.add(tid)
                self.speculative_launches += 1
                return self.inflight[tid]
        return None

    def next_speculation_time(self) -> Optional[float]:
        """Earliest clock time at which some in-flight task becomes
        speculation-eligible (None when speculation is off or nothing
        qualifies) — the virtual-time driver re-polls idle workers at
        exactly this moment instead of on a coarse exec-EMA grid, so a
        clone launches the instant the cost model allows it."""
        if not (self.cfg.speculative and self.avg_exec):
            return None
        factor = self.cfg.effective_straggler_factor()
        if self.cfg.speculative == "auto":
            # should_speculate additionally needs gain > clone tax
            factor = max(factor, 1.0 + rec.SPECULATION_CLONE_TAX)
        times = [started + factor * self.avg_exec
                 for tid, started in self._started_at.items()
                 if tid not in self._speculated]
        if not times:
            return None
        return min(times) + 1e-9       # strict-inequality epsilon

    def claim_batch(self, worker: int, first: Task, max_n: int,
                    key_fn: Callable[[Task], Any]) -> List[Task]:
        """Wave draining: extend ``first`` (already claimed via
        :meth:`on_worker_idle`) with more ready tasks whose shape key
        matches, popped FIFO from this worker's own queue and then the
        backlog.  The first key mismatch stops the drain so waves stay
        same-shape (one compiled kernel per wave); the caller bounds
        ``max_n`` (the driver sizes it per shape bucket so every worker
        gets a fair share and one worker cannot swallow the backlog).
        The caller must :meth:`on_task_start` every claimed task.

        Crash recovery tracks the FULL wave: every claimed task lands in
        ``claims_by_worker`` at :meth:`on_task_start`, so
        :meth:`on_worker_crash` / :meth:`reclaim_expired` recover every
        wave member of a dead worker, not just the last one (the legacy
        single-slot ``inflight_by_worker`` only feeds straggler
        speculation)."""
        q = self.queues[worker]
        out = [first]
        key = key_fn(first)
        while len(out) < max_n and q and key_fn(q[0]) == key:
            t = q.popleft()
            if t.task_id not in self._completed:
                out.append(t)
        while (len(out) < max_n and self.backlog
               and key_fn(self.backlog[0]) == key):
            t = self.backlog.popleft()
            if t.task_id not in self._completed:
                out.append(t)
        return out

    def cancel_pending(self) -> List[Task]:
        """DRAINING (DESIGN.md §10): drop every not-yet-started task —
        the backlog and all per-worker queues — leaving in-flight tasks
        to settle normally, after which :meth:`done` turns true.  The
        early-termination analogue of :meth:`MultiJobScheduler.
        cancel_job`; idempotent, returns what was dropped so the driver
        can account ``tasks_cancelled``."""
        dropped: List[Task] = list(self.backlog)
        self.backlog.clear()
        for q in self.queues:
            dropped.extend(q)
            q.clear()
        self.cancelled_tasks += len(dropped)
        if dropped:
            self.telemetry.emit("job_draining", n_cancelled=len(dropped))
        return dropped

    def on_worker_failure(self, worker: int) -> List[Task]:
        """Job-level: raise (driver restarts whole job).  Task-level:
        reclaim the dead worker's queued+inflight tasks for re-execution."""
        self._alive[worker] = False
        if self.cfg.recovery == "job":
            raise JobFailure(f"worker {worker} failed; job-level restart",
                             failed_worker=worker)
        reclaimed = list(self.queues[worker])
        self.queues[worker].clear()
        own = self.inflight_by_worker.pop(worker, None)
        claims = self.claims_by_worker.pop(worker, {})
        if own is not None:
            claims.setdefault(own.task_id, own)
        for t in claims.values():
            self.inflight.pop(t.task_id, None)
            self._lease.pop(t.task_id, None)
            reclaimed.append(t)
        for t in reclaimed:
            # reset the straggler clock: the re-execution must not
            # inherit the dead worker's elapsed time (it would be
            # instantly speculation-eligible)
            self._started_at.pop(t.task_id, None)
            self._first_worker.pop(t.task_id, None)
        self.backlog.extend(reclaimed)
        return reclaimed

    def on_worker_crash(self, worker: int, *,
                        respawn: bool = True) -> List[Task]:
        """A worker thread died mid-task (detected or injected): requeue
        EVERY claimed-but-unsettled task it held — the whole wave, plus
        its queued work — at the FRONT of the backlog so recovery work
        drains first.  Unlike :meth:`on_worker_failure` this never
        aborts the job: the runner respawns the worker under the same id
        (``respawn=True`` keeps it alive in the scheduler) and
        first-completion-wins dedup keeps any late settlement from the
        dead thread harmless.  Idempotent per crash."""
        self.worker_crashes += 1
        reclaimed = [t for t in self.queues[worker]
                     if t.task_id not in self._completed]
        self.queues[worker].clear()
        self.inflight_by_worker.pop(worker, None)
        claims = self.claims_by_worker.pop(worker, {})
        for tid, t in claims.items():
            if tid not in self._completed:
                reclaimed.append(t)
        seen: set = set()
        requeue: List[Task] = []
        for t in reclaimed:
            if t.task_id in seen:
                continue
            seen.add(t.task_id)
            self.inflight.pop(t.task_id, None)
            self._lease.pop(t.task_id, None)
            self._started_at.pop(t.task_id, None)
            self._first_worker.pop(t.task_id, None)
            requeue.append(t)
        self.backlog.extendleft(reversed(requeue))
        self.reclaimed_tasks += len(requeue)
        self.telemetry.emit("worker_crash", worker=worker,
                            reclaimed=len(requeue), respawn=respawn)
        if not respawn:
            self._alive[worker] = False
        return requeue

    def reclaim_expired(self, now: Optional[float] = None) -> List[Task]:
        """Lease expiry sweep (drivers call this from idle workers): any
        claimed task whose lease has lapsed is requeued at the front of
        the backlog for re-execution — the safety net for workers that
        die without a detectable crash.  The original claim stays live
        (a slow-but-alive worker may still settle first; dedup keeps it
        at-most-once), so the re-execution behaves exactly like a
        speculative clone with the task's own seed: bit-identical."""
        if self.cfg.lease_seconds is None or not self._lease:
            return []
        t_now = time.perf_counter() if now is None else now
        expired = [tid for tid, exp in self._lease.items()
                   if exp <= t_now and tid not in self._completed]
        out: List[Task] = []
        for tid in expired:
            self._lease.pop(tid, None)
            task = self.inflight.get(tid)
            if task is None:
                continue
            # reset the straggler clock for the re-execution
            self._started_at.pop(tid, None)
            self.backlog.appendleft(task)
            self.reclaimed_tasks += 1
            out.append(task)
        if out:
            self.telemetry.emit(
                "lease_reclaimed", n=len(out),
                task_ids=tuple(t.task_id for t in out))
        return out

    def on_tasks_lost(self, worker: int, tasks: Sequence[Task]) -> None:
        """Permanently drop claimed tasks whose data is gone (every
        replica down, retry budget spent): settle them OUT of the
        in-flight accounting without marking them completed, so a
        degraded drain can finish from what actually executed instead of
        hanging on tasks that can never settle."""
        claims = self.claims_by_worker.get(worker, {})
        for t in tasks:
            if t.task_id in self._completed:
                continue
            claims.pop(t.task_id, None)
            self.inflight.pop(t.task_id, None)
            self._lease.pop(t.task_id, None)
            self._started_at.pop(t.task_id, None)
            self._first_worker.pop(t.task_id, None)
            self.lost_tasks += 1
        self.inflight_by_worker.pop(worker, None)

    def done(self) -> bool:
        return (not self.backlog and not self.inflight
                and all(not q for q in self.queues))


# ---------------------------------------------------------------------------
# Multi-job service scheduling (service layer, DESIGN.md §8)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MultiJobConfig:
    quantum: float = 8.0          # DRR credit added per visit (tasks)
    deadline_headroom: float = 1.5   # boost when slack < headroom·remaining
    default_task_seconds: float = 1e-3   # est. before any completion
    # straggler speculation (False | True | "auto" — as SchedulerConfig):
    # idle pool workers clone in-flight tasks older than
    # straggler_factor × the pool-wide exec EMA; first completion wins
    speculative: Any = False
    straggler_factor: float = 2.0
    # lease-based reclamation across the pool (None disables): claimed
    # tasks whose lease lapses are requeued to their job's front
    lease_seconds: Optional[float] = None


@dataclasses.dataclass
class ServiceJob:
    """One admitted job's scheduling state: a FIFO of job-tagged tasks
    plus the deficit-round-robin / deadline bookkeeping."""

    job_id: int
    pending: "deque[Task]"
    n_tasks: int
    fuse_key: Callable[[Task], Any]     # cross-job wave-fusion identity
    cap: Callable[[Task], int]          # wave width for a task's bucket
    priority: int = 0
    deadline: Optional[float] = None    # absolute (caller's clock)
    weight: float = 1.0
    deficit: float = 0.0
    inflight: int = 0
    completed: int = 0
    # response-time locality (predicted best-replica fetch seconds)
    locality_score: Optional[Callable[[Task], float]] = None
    # straggler-speculation bookkeeping (first completion wins)
    inflight_tasks: Dict[int, Task] = dataclasses.field(default_factory=dict)
    started_at: Dict[int, float] = dataclasses.field(default_factory=dict)
    speculated: set = dataclasses.field(default_factory=set)
    completed_ids: set = dataclasses.field(default_factory=set)

    @property
    def done(self) -> bool:
        return self.completed >= self.n_tasks


class MultiJobScheduler:
    """Ready-queue policy for many concurrent jobs on one resident pool.

    Pure policy, externally locked (like :class:`TwoPhaseScheduler`):
    the service pool calls :meth:`claim` under its lock and executes the
    returned batch outside it.

    * **Fairness** — deficit round robin across jobs, at *wave*
      granularity: serving a job credits it ``quantum × weight``
      task-units and debits the tasks actually taken, and each claim
      picks the least-served ready job (highest deficit, round-robin
      order breaking ties) in the highest priority tier — so a
      1000-task job cannot starve an 8-task job.  A wave is never
      truncated below its bucket width (padding would waste the
      difference); the deficit only carries the imbalance forward.
    * **Deadline boost** — a job whose slack (deadline − now) falls
      under ``deadline_headroom ×`` its estimated remaining runtime
      jumps the round-robin order (earliest deadline first among the
      urgent).
    * **Cross-job wave fusion** — a claimed batch starts FIFO from the
      chosen job and is then *filled* with ready tasks from other jobs
      whose ``fuse_key`` matches (same dataset arena + engine + block
      shape), so one device dispatch serves several jobs.  Fused tasks
      are charged to their own job's deficit, keeping fairness intact.
    """

    def __init__(self, n_workers: int,
                 cfg: MultiJobConfig = MultiJobConfig(), *,
                 telemetry=None):
        self.cfg = cfg
        if telemetry is None:
            from repro.platform.telemetry import null_bus
            telemetry = null_bus()
        self.telemetry = telemetry
        self.n_workers = max(n_workers, 1)
        self.jobs: Dict[int, ServiceJob] = {}
        self._rr: deque[int] = deque()      # active round-robin order
        self.avg_task_seconds: Optional[float] = None
        self.fused_dispatches = 0           # batches spanning >1 job
        self.claims = 0
        self.speculative_launches = 0
        self.speculation_wins = 0
        self._rank_dirty = False
        self.reranks = 0
        # crash/lease recovery: every claimed-but-unsettled (job, task)
        # per worker, and per-claim lease expiries
        self.claimed_by: Dict[int, Dict[Tuple[int, int], Task]] = {}
        self._lease: Dict[Tuple[int, int], float] = {}
        self.worker_crashes = 0
        self.reclaimed_tasks = 0
        self.lost_tasks = 0

    # -- job lifecycle -------------------------------------------------------
    def add_job(self, job_id: int, tasks: Sequence[Task], *,
                fuse_key: Optional[Callable[[Task], Any]] = None,
                cap: Any = 1, priority: int = 0,
                deadline: Optional[float] = None,
                weight: float = 1.0,
                locality_score: Optional[Callable[[Task], float]] = None,
                ) -> ServiceJob:
        if job_id in self.jobs:
            raise ValueError(f"job {job_id} already scheduled")
        cap_fn = cap if callable(cap) else (lambda t, _c=int(cap): _c)
        job = ServiceJob(
            job_id=job_id, pending=deque(tasks), n_tasks=len(tasks),
            fuse_key=fuse_key or (lambda t: (job_id, t.task_id)),
            cap=cap_fn, priority=priority, deadline=deadline,
            weight=weight, locality_score=locality_score)
        if locality_score is not None:
            self._rank_job(job)
        self.jobs[job_id] = job
        self._rr.append(job_id)
        return job

    # -- response-time-aware claim ordering ----------------------------------
    def request_rerank(self) -> None:
        """Mark every job's ready ranking stale (safe from any thread —
        re-sorting happens inside the next :meth:`claim`, under the
        pool's lock)."""
        self._rank_dirty = True

    def _maybe_rerank(self) -> None:
        if not self._rank_dirty:
            return
        self._rank_dirty = False
        for job in self.jobs.values():
            if job.locality_score is not None:
                self._rank_job(job)

    def _rank_job(self, job: ServiceJob) -> None:
        if len(job.pending) <= 1:
            return
        job.pending = rank_by_bucket(job.pending, job.fuse_key,
                                     job.locality_score)
        self.reranks += 1

    def cancel_job(self, job_id: int) -> List[Task]:
        """Drop a job's queued tasks (in-flight ones finish and are
        discarded by the caller); returns what was dropped."""
        job = self.jobs.get(job_id)
        if job is None:
            return []
        dropped = list(job.pending)
        job.pending.clear()
        job.n_tasks -= len(dropped)
        self._drop_from_rotation(job_id)
        if job.inflight == 0:
            self.jobs.pop(job_id, None)
        return dropped

    def fail_job(self, job_id: int) -> None:
        """Remove a job whose batch errored: queued tasks are dropped and
        in-flight accounting is abandoned (the pool already owns the
        error fan-out); peers are unaffected — recovery is job-level,
        per job (thesis §3.3 applied per tenant)."""
        job = self.jobs.pop(job_id, None)
        if job is not None:
            job.pending.clear()
        self._drop_from_rotation(job_id)

    def _drop_from_rotation(self, job_id: int) -> None:
        """A job leaving ``self.jobs`` (or losing all pending tasks) must
        leave ``_rr`` too: :meth:`_pick` only prunes stale ids at the
        *front* of the rotation, so a mid-rotation leftover would index a
        popped job."""
        try:
            self._rr.remove(job_id)
        except ValueError:
            pass

    def pending_tasks(self) -> int:
        return sum(len(j.pending) for j in self.jobs.values())

    def has_ready(self) -> bool:
        return any(j.pending for j in self.jobs.values())

    def peek(self, n: int, now: float = 0.0) -> List[Tuple[ServiceJob,
                                                           Task]]:
        """Up to ``n`` upcoming (job, task) pairs, without claiming —
        the prefetcher's look-ahead window.  Ordered like :meth:`claim`
        would serve them (deadline-urgent job first, then priority tier
        and deficit, rotation breaking ties): a rotation-order peek
        would warm the WRONG job's fetches whenever a boost or a
        priority tier redirects the next claim."""
        rot = {jid: i for i, jid in enumerate(self._rr)}
        ready = [j for jid in self._rr
                 if (j := self.jobs.get(jid)) is not None and j.pending]
        ready.sort(key=lambda j: (-j.priority, -j.deficit,
                                  rot.get(j.job_id, 0)))
        urgent = self._urgent(now)
        if urgent is not None:
            ready = [urgent] + [j for j in ready if j is not urgent]
        out: List[Tuple[ServiceJob, Task]] = []
        for job in ready:
            for t in job.pending:
                out.append((job, t))
                if len(out) >= n:
                    return out
        return out

    # -- deadline model ------------------------------------------------------
    def _task_seconds(self) -> float:
        return self.avg_task_seconds or self.cfg.default_task_seconds

    def est_remaining(self, job: ServiceJob) -> float:
        """Remaining runtime if the pool served only this job."""
        left = len(job.pending) + job.inflight
        return left * self._task_seconds() / self.n_workers

    def _urgent(self, now: float) -> Optional[ServiceJob]:
        urgent = [j for j in self.jobs.values()
                  if j.pending and j.deadline is not None
                  and (j.deadline - now) < (self.cfg.deadline_headroom
                                            * self.est_remaining(j))]
        if not urgent:
            return None
        return min(urgent, key=lambda j: j.deadline)

    # -- claim ---------------------------------------------------------------
    def _pick(self, now: float) -> Optional[ServiceJob]:
        # lazily drop drained/cancelled entries from the rotation
        while self._rr and (self._rr[0] not in self.jobs
                            or not self.jobs[self._rr[0]].pending):
            self._rr.popleft()
        boosted = self._urgent(now)
        if boosted is not None:
            return boosted
        # ``.get``: defensive against rotation entries whose job was
        # removed out-of-band — never KeyError inside a pool worker
        ready = [j for jid in self._rr
                 if (j := self.jobs.get(jid)) is not None and j.pending]
        if not ready:
            return None
        top = max(j.priority for j in ready)
        tier = [j for j in ready if j.priority == top]
        # least-served first: highest deficit; ties fall to round-robin
        # order (max() keeps the first maximum, and served jobs rotate
        # to the back of ``_rr``)
        return max(tier, key=lambda j: j.deficit)

    def claim(self, now: float, max_n: Optional[int] = None,
              worker: Optional[int] = None) -> List[Tuple[ServiceJob,
                                                          Task]]:
        """Claim the next batch for an idle worker: ``[]`` when nothing
        is ready.  Every claimed task is marked in-flight; the caller
        reports each back through :meth:`on_task_complete`.  ``worker``
        tags the claim for crash/lease reclamation (a dead worker's
        claims are requeued by :meth:`on_worker_dead`)."""
        self._maybe_rerank()
        job = self._pick(now)
        if job is None:
            return []
        self.claims += 1
        job.deficit += self.cfg.quantum * job.weight
        first = job.pending[0]
        key = job.fuse_key(first)
        cap = max(int(job.cap(first)), 1)
        if max_n is not None:
            cap = min(cap, max_n)
        batch: List[Tuple[ServiceJob, Task]] = []
        while (job.pending and len(batch) < cap
               and job.fuse_key(job.pending[0]) == key):
            t = job.pending.popleft()
            # a lease-reclaimed duplicate whose original settled is
            # dropped at claim time, never re-executed
            if t.task_id in job.completed_ids:
                continue
            batch.append((job, t))
        # debit what was actually served; cap the carried credit at one
        # quantum so an idle-ish job cannot hoard turns
        job.deficit = min(job.deficit - len(batch), self.cfg.quantum)
        # rotate the served job to the back of the round-robin order
        self._drop_from_rotation(job.job_id)
        if job.pending:
            self._rr.append(job.job_id)
        # cross-job fusion fill: same fuse key, FIFO from each peer
        if cap > 1 and len(batch) < cap:
            for jid in list(self._rr):
                peer = self.jobs.get(jid)
                if peer is None or peer is job:
                    continue
                took = 0
                while (peer.pending and len(batch) < cap
                       and peer.fuse_key(peer.pending[0]) == key):
                    t = peer.pending.popleft()
                    if t.task_id in peer.completed_ids:
                        continue
                    batch.append((peer, t))
                    took += 1
                if took:
                    peer.deficit -= took    # fused service still counts
        if len({j.job_id for j, _ in batch}) > 1:
            self.fused_dispatches += 1
        for j, t in batch:
            j.inflight += 1
            j.inflight_tasks[t.task_id] = t
            j.started_at.setdefault(t.task_id, now)
            self._record_claim(worker, j.job_id, t, now)
        if batch:
            by_job: Dict[int, List[int]] = {}
            for j, t in batch:
                by_job.setdefault(j.job_id, []).append(t.task_id)
            for jid, tids in by_job.items():
                self.telemetry.emit("task_claimed", job_id=jid,
                                    task_ids=tuple(tids), worker=worker)
        return batch

    def _record_claim(self, worker: Optional[int], job_id: int,
                      task: Task, now: float) -> None:
        if worker is not None:
            self.claimed_by.setdefault(worker, {})[
                (job_id, task.task_id)] = task
        if self.cfg.lease_seconds is not None:
            self._lease[(job_id, task.task_id)] = (
                now + self.cfg.lease_seconds)

    def claim_speculative(self, now: float,
                          cfg_speculative: Any = None,
                          worker: Optional[int] = None,
                          ) -> List[Tuple[ServiceJob, Task]]:
        """Straggler speculation for an idle pool worker when nothing is
        ready: clone the oldest in-flight task whose age exceeds
        ``straggler_factor ×`` the pool-wide exec EMA (``"auto"`` mode
        additionally requires the clone to beat its standing tax per the
        §3.3 cost model).  The clone re-executes with the task's own
        seed, so first-completion-wins is bit-exact; each task is cloned
        at most once."""
        speculative = (self.cfg.speculative if cfg_speculative is None
                       else cfg_speculative)
        ema = self.avg_task_seconds
        if not speculative or not ema:
            return []
        factor = self.cfg.straggler_factor
        best: Optional[Tuple[float, ServiceJob, Task]] = None
        for job in self.jobs.values():
            for tid, started in job.started_at.items():
                if tid in job.speculated or tid in job.completed_ids:
                    continue
                task = job.inflight_tasks.get(tid)
                if task is None:
                    continue
                age = now - started
                if age <= factor * ema:
                    continue
                if speculative == "auto" and not rec.should_speculate(
                        age, ema, straggler_factor=factor):
                    continue
                if best is None or age > best[0]:
                    best = (age, job, task)
        if best is None:
            return []
        _, job, task = best
        job.speculated.add(task.task_id)
        job.inflight += 1
        self.speculative_launches += 1
        self._record_claim(worker, job.job_id, task, now)
        return [(job, task)]

    def on_task_abandoned(self, job_id: int, task_id: int,
                          worker: Optional[int] = None) -> None:
        """Settle a claimed task that will never complete — a
        speculative clone whose execution failed.  In-flight accounting
        only: the original still owns completion, and a lost redundant
        bet must never fail or finish the job."""
        if worker is not None:
            self.claimed_by.get(worker, {}).pop((job_id, task_id), None)
        job = self.jobs.get(job_id)
        if job is not None:
            job.inflight -= 1

    def on_task_complete(self, job_id: int,
                         exec_seconds: Optional[float],
                         task_id: Optional[int] = None,
                         speculative: bool = False,
                         worker: Optional[int] = None,
                         fetch_seconds: Optional[float] = None) -> bool:
        """Record one finished task; True when its job just completed.
        ``exec_seconds`` feeds the per-task-seconds EMA the deadline
        model uses; pass ``None`` to settle in-flight accounting without
        a timing sample (tasks claimed from an already-cancelled job
        never execute, and a 0.0 sample would drag the deadline-boost
        and admission estimates toward zero).  ``task_id`` enables
        first-completion-wins accounting for speculative clones: the
        duplicate completion settles the in-flight count without
        double-counting progress.  ``speculative`` marks a completion
        delivered by a :meth:`claim_speculative` batch — a clone only
        counts as a *win* when it, not the original, completed first."""
        if exec_seconds is not None:
            a = 0.3
            self.avg_task_seconds = (
                exec_seconds if self.avg_task_seconds is None
                else (1 - a) * self.avg_task_seconds + a * exec_seconds)
        if worker is not None and task_id is not None:
            self.claimed_by.get(worker, {}).pop((job_id, task_id), None)
        if task_id is not None:
            self._lease.pop((job_id, task_id), None)
        job = self.jobs.get(job_id)
        if job is None:
            return False
        job.inflight -= 1
        duplicate = (task_id is not None and task_id in job.completed_ids)
        if not duplicate:
            # depth/fetch_seconds feed the monitor's queue-depth SLI and
            # critical-path fetch attribution (DESIGN.md §15) — the
            # single-job scheduler's settle carries the same fields
            self.telemetry.emit(
                "task_settled", job_id=job_id, task_id=task_id,
                worker=worker, exec_seconds=exec_seconds,
                fetch_seconds=fetch_seconds, speculative=speculative,
                depth=sum(len(j.pending) for j in self.jobs.values()))
            job.completed += 1
            if task_id is not None:
                job.completed_ids.add(task_id)
                if speculative and task_id in job.speculated:
                    self.speculation_wins += 1
                job.inflight_tasks.pop(task_id, None)
                job.started_at.pop(task_id, None)
        # with task ids, genuine outstanding work is inflight_tasks —
        # the job completes at its FIRST full completion even while a
        # speculative clone still races (the duplicate settles against a
        # job that has already left the table); legacy callers without
        # task ids fall back to the raw in-flight count
        finished = (job.done
                    and ((not job.inflight_tasks) if task_id is not None
                         else job.inflight == 0))
        if finished and job.pending:
            # crash/lease requeues can leave already-completed
            # duplicates in pending; they never execute, so the job
            # finishes when every pending entry is such a duplicate
            finished = all(t.task_id in job.completed_ids
                           for t in job.pending)
            if finished:
                job.pending.clear()
                self._drop_from_rotation(job_id)
        if finished:
            self.jobs.pop(job_id, None)
            return True
        return False

    # -- crash / lease reclamation (DESIGN.md §12) ---------------------------
    def on_worker_dead(self, worker: int) -> List[Tuple[int, Task]]:
        """A pool worker thread died: requeue every claimed-but-
        unsettled task it held to the FRONT of its job's pending queue
        (recovery work drains first).  Settlement stays at-most-once —
        completed ids are skipped here and duplicates are dropped at
        claim time — so results are bit-identical to the fault-free
        run.  Returns the requeued (job_id, task) pairs."""
        self.worker_crashes += 1
        self.telemetry.emit("worker_crash", worker=worker)
        claims = self.claimed_by.pop(worker, {})
        requeued: List[Tuple[int, Task]] = []
        for (jid, tid), task in claims.items():
            self._lease.pop((jid, tid), None)
            job = self.jobs.get(jid)
            if job is None or tid in job.completed_ids:
                continue
            job.inflight -= 1
            job.inflight_tasks.pop(tid, None)
            job.started_at.pop(tid, None)
            job.speculated.discard(tid)
            job.pending.appendleft(task)
            if jid not in self._rr:
                self._rr.append(jid)
            self.reclaimed_tasks += 1
            requeued.append((jid, task))
        return requeued

    def reclaim_expired(self, now: float) -> List[Tuple[int, Task]]:
        """Lease-expiry sweep (idle pool workers call this): requeue
        claimed tasks whose lease lapsed.  The original claim's
        accounting stays live (a slow worker may still settle first —
        dedup keeps it at-most-once); the re-execution runs with the
        task's own seed, so the race is bit-identical either way."""
        if self.cfg.lease_seconds is None or not self._lease:
            return []
        expired = [k for k, exp in self._lease.items() if exp <= now]
        out: List[Tuple[int, Task]] = []
        for jid, tid in expired:
            self._lease.pop((jid, tid), None)
            job = self.jobs.get(jid)
            if job is None or tid in job.completed_ids:
                continue
            task = job.inflight_tasks.get(tid)
            if task is None:
                continue
            # like a speculative clone: the original may still settle
            job.speculated.add(tid)
            job.pending.appendleft(task)
            if jid not in self._rr:
                self._rr.append(jid)
            self.reclaimed_tasks += 1
            out.append((jid, task))
        if out:
            self.telemetry.emit(
                "lease_reclaimed", n=len(out),
                task_ids=tuple(t.task_id for _, t in out),
                job_ids=tuple(jid for jid, _ in out))
        return out

    def on_task_lost(self, job_id: int, task_id: int,
                     worker: Optional[int] = None) -> bool:
        """Permanent loss (every replica of the task's data is gone):
        settle the claim WITHOUT completion and shrink the job so a
        degraded drain can finish from what actually executed.  Returns
        True when the job just finished (degraded)."""
        if worker is not None:
            self.claimed_by.get(worker, {}).pop((job_id, task_id), None)
        self._lease.pop((job_id, task_id), None)
        job = self.jobs.get(job_id)
        if job is None:
            return False
        job.inflight -= 1
        job.inflight_tasks.pop(task_id, None)
        job.started_at.pop(task_id, None)
        if task_id not in job.completed_ids:
            job.n_tasks -= 1
            self.lost_tasks += 1
        finished = (job.done
                    and not job.inflight_tasks
                    and all(t.task_id in job.completed_ids
                            for t in job.pending))
        if finished:
            job.pending.clear()
            self._drop_from_rotation(job_id)
            self.jobs.pop(job_id, None)
            return True
        return False


# ---------------------------------------------------------------------------
# Discrete-event simulation driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimWorker:
    worker_id: int
    speed: float = 1.0                 # <1 ⇒ slower (heterogeneity, Fig 14)
    fail_at: Optional[float] = None    # inject a failure at this sim time


@dataclasses.dataclass
class SimParams:
    """Per-task cost model, calibrated from real measured runs."""
    exec_time: Callable[[Task], float]       # seconds on a speed-1.0 worker
    fetch_time: Callable[[Task], float]      # data-fetch latency
    launch_overhead: float = 0.0             # per-task start cost (Fig 5/6)
    startup_time: float = 0.0                # one-time job startup


@dataclasses.dataclass
class SimOutcome:
    makespan: float
    results: List[TaskResult]
    per_worker_busy: Dict[int, float]
    restarts: int = 0
    queue_depths: List[int] = dataclasses.field(default_factory=list)
    speculative_launches: int = 0
    speculation_wins: int = 0


def simulate_job(
    tasks: Sequence[Task],
    workers: Sequence[SimWorker],
    params: SimParams,
    cfg: SchedulerConfig = SchedulerConfig(),
    *,
    max_restarts: int = 3,
    locality_score: Optional[Callable[[Task], float]] = None,
    bucket_key: Optional[Callable[[Task], Any]] = None,
    stopper=None,
    telemetry=None,
) -> SimOutcome:
    """Run the two-phase scheduler under virtual time.  Prefetch overlap:
    a task's data fetch for queued work proceeds while the previous task
    executes, so effective per-task cost is max(exec, fetch) once the
    queue is warm (exactly the paper's pipeline in §3.5).  ``stopper`` —
    a :class:`~repro.core.estimator.StoppingController` — is fed each
    completion and, once converged, the backlog is cancelled (DRAINING):
    the early-termination decision lands at the same completed-task
    count a real cluster would reach it at."""
    restarts = 0
    alive = list(workers)
    while True:
        try:
            return _simulate_once(tasks, alive, params, cfg, restarts,
                                  locality_score=locality_score,
                                  bucket_key=bucket_key, stopper=stopper,
                                  telemetry=telemetry)
        except JobFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            if stopper is not None:
                # job-level restart discards and re-executes every
                # completion; a latched (or partially fed) stopper would
                # drain the retry at its first settlement with an answer
                # far thinner than its recorded convergence claims
                stopper.reset()
            # the dead node does not rejoin; the job restarts on survivors
            survivors = [w for w in alive
                         if w.worker_id != e.failed_worker]
            if survivors:
                alive = survivors


def _simulate_once(tasks, workers, params, cfg, restarts, *,
                   locality_score=None, bucket_key=None,
                   stopper=None, telemetry=None) -> SimOutcome:
    """Worker identity inside the scheduler is positional (0..n-1); the
    SimWorker.worker_id is only used for reporting (survivor restarts
    renumber positions but keep ids)."""
    sched = TwoPhaseScheduler(len(workers), tasks, cfg,
                              locality_score=locality_score,
                              bucket_key=bucket_key, telemetry=telemetry)
    bus = sched.telemetry
    now = params.startup_time
    busy: Dict[int, float] = {w.worker_id: 0.0 for w in workers}
    # event heap: (time, seq, kind, worker_index, task)
    seq = itertools.count()
    heap: List[Tuple[float, int, str, int, Optional[Task]]] = []
    cost_mult = 1.0 + (cfg.cost_tl if cfg.recovery == "task" else 0.0)

    def task_cost(w: SimWorker, t: Task, queue_warm: bool) -> Tuple[float, float, float]:
        fetch = params.fetch_time(t)
        ex = (params.exec_time(t) / w.speed + params.launch_overhead) * cost_mult
        # warm queue ⇒ fetch overlapped with previous execution
        total = max(ex, fetch) if queue_warm else ex + fetch
        return total, fetch, ex

    for i, w in enumerate(workers):
        if w.fail_at is not None:
            heapq.heappush(heap, (w.fail_at, next(seq), "fail", i, None))

    for widx, task in sched.initial_assignments():
        t = sched.on_worker_idle(widx, now)
        if t is None:
            continue
        sched.on_task_start(widx, t, now)
        bus.emit("task_claimed", ts=now, task_ids=(t.task_id,),
                 worker=widx)
        total, fetch, ex = task_cost(workers[widx], t, queue_warm=False)
        heapq.heappush(heap, (now + total, next(seq), "done", widx, t))
        busy[workers[widx].worker_id] += total

    makespan = now
    has_event = [True] * len(workers)

    def dispatch(widx: int, at: float):
        nxt = sched.on_worker_idle(widx, at)
        if nxt is not None:
            sched.on_task_start(widx, nxt, at)
            bus.emit("task_claimed", ts=at, task_ids=(nxt.task_id,),
                     worker=widx)
            total, _, _ = task_cost(workers[widx], nxt, queue_warm=True)
            heapq.heappush(heap, (at + total, next(seq), "done", widx, nxt))
            busy[workers[widx].worker_id] += total
            has_event[widx] = True
        elif cfg.speculative and not sched.done() and sched.avg_exec:
            # re-poll exactly when a straggler first becomes
            # speculation-eligible (fall back to an exec-EMA tick)
            eligible_at = sched.next_speculation_time()
            when = (max(eligible_at, at + 1e-9) if eligible_at is not None
                    else at + sched.avg_exec)
            heapq.heappush(heap, (when, next(seq), "poll", widx, None))
            has_event[widx] = True

    while heap:
        now, _, kind, widx, task = heapq.heappop(heap)
        if kind == "fail":
            if sched.done():
                continue
            try:
                sched.on_worker_failure(widx)   # raises under job-level
            except JobFailure:
                # translate positional index to the stable worker id so the
                # restart loop can exclude the dead node
                raise JobFailure(
                    f"worker {workers[widx].worker_id} failed; "
                    "job-level restart",
                    failed_worker=workers[widx].worker_id) from None
            has_event[widx] = False
            # reclaimed tasks: wake any idle living workers
            for i in range(len(workers)):
                if sched._alive[i] and not has_event[i]:
                    dispatch(i, now)
            continue
        has_event[widx] = False
        if kind == "poll":
            if not sched.done():
                dispatch(widx, now)
            continue
        if not sched._alive[widx]:
            continue                        # completion from a dead worker
        total_prev, fetch, ex = task_cost(workers[widx], task,
                                          queue_warm=True)
        res = TaskResult(task.task_id, widx, now - total_prev, fetch, ex)
        # a straggler superseded by its speculative copy doesn't extend
        # the job (its late completion is discarded)
        is_dup = task.task_id in sched._completed
        sched.on_task_complete(res, ts=now)
        if not is_dup:
            makespan = max(makespan, now)
            if stopper is not None:
                # wave-settlement stopping check (DESIGN.md §10): on
                # convergence the ready work is dropped; the in-flight
                # "done" events already on the heap settle normally
                stopper.on_complete(task.task_id)
                if stopper.should_stop():
                    sched.cancel_pending()
        dispatch(widx, now)
    return SimOutcome(makespan=makespan, results=sched.results,
                      per_worker_busy=busy, restarts=restarts,
                      queue_depths=list(sched.depth_trace),
                      speculative_launches=sched.speculative_launches,
                      speculation_wins=sched.speculation_wins)


# ---------------------------------------------------------------------------
# Threaded driver (real wall time)
# ---------------------------------------------------------------------------


class ThreadedRunner:
    """Executes tasks with real threads; one queue per worker.  The worker
    callable receives (task) and returns a value; fetch is performed by the
    optional datastore before execution (overlapped via the queue).

    Wave mode: with ``run_batch`` set (and ``max_batch > 1``), an idle
    worker drains up to ``max_batch`` ready tasks of the same ``batch_key``
    shape in one claim and executes them through ``run_batch(tasks) ->
    values`` — one device dispatch per wave instead of per task.  Each
    task still yields its own :class:`TaskResult` (exec time split evenly)
    so the feedback loop and straggler accounting are unchanged."""

    def __init__(self, n_workers: int,
                 run_task: Callable[[Task], Any],
                 fetch: Optional[Callable[[Task], Any]] = None,
                 cfg: SchedulerConfig = SchedulerConfig(),
                 run_batch: Optional[Callable[[List[Task]],
                                              List[Any]]] = None,
                 batch_key: Optional[Callable[[Task], Any]] = None,
                 max_batch: int = 1,
                 batch_cap: Optional[Callable[[Task], int]] = None,
                 locality_score: Optional[Callable[[Task], float]] = None,
                 prefetcher=None, stopper=None,
                 crash_hook: Optional[Callable[[int], None]] = None,
                 max_respawns: int = 2,
                 telemetry=None):
        self.n_workers = n_workers
        if telemetry is None:
            from repro.platform.telemetry import null_bus
            telemetry = null_bus()
        self.telemetry = telemetry
        self.run_task = run_task
        self.fetch = fetch
        self.cfg = cfg
        self.run_batch = run_batch
        self.batch_key = batch_key or (lambda t: len(t.sample_ids))
        self.max_batch = max_batch
        # fault injection (repro.platform.faults): called with the
        # worker id right after each claim; raises WorkerCrash to
        # simulate the thread dying mid-task
        self.crash_hook = crash_hook
        # per-worker respawn budget: a crashed worker thread is
        # restarted under the same id until the budget runs out, after
        # which its work is reclaimed and the pool shrinks
        self.max_respawns = max_respawns
        self.worker_respawns = 0
        # per-shape wave-size cap (the driver pins one padded wave width
        # per shape bucket; claims must not exceed it)
        self.batch_cap = batch_cap
        # response-time-aware ranking + dynamic-k ahead-fetch (the
        # balanced scheduling loop, DESIGN.md §9)
        self.locality_score = locality_score
        self.prefetcher = prefetcher       # core.prefetch.TaskPrefetcher
        # error-bounded early termination (DESIGN.md §10): a
        # core.estimator.StoppingController consulted at every wave
        # settlement; on convergence the scheduler drains
        self.stopper = stopper
        # called with the live scheduler before workers start (drivers
        # wire data-plane state changes to request_rerank here)
        self.on_scheduler: Optional[Callable[[TwoPhaseScheduler],
                                             None]] = None
        self.last_scheduler: Optional[TwoPhaseScheduler] = None

    def run_job(self, tasks: Sequence[Task]) -> List[TaskResult]:
        sched = TwoPhaseScheduler(self.n_workers, tasks, self.cfg,
                                  locality_score=self.locality_score,
                                  bucket_key=self.batch_key,
                                  telemetry=self.telemetry)
        self.last_scheduler = sched
        if self.on_scheduler is not None:
            self.on_scheduler(sched)
        lock = threading.Lock()
        results: List[TaskResult] = []
        errors: List[BaseException] = []
        use_waves = self.run_batch is not None and self.max_batch > 1

        prefetcher = self.prefetcher if self.fetch is not None else None

        def worker_loop(wid: int):
            while True:
                batch = None
                upcoming: List[Task] = []
                with lock:
                    if errors:                 # a peer died: job-level
                        return                 # abort (thesis §3.3)
                    t = sched.on_worker_idle(wid)
                    if t is not None:
                        if use_waves:
                            cap = (min(self.max_batch, self.batch_cap(t))
                                   if self.batch_cap else self.max_batch)
                            batch = sched.claim_batch(wid, t, cap,
                                                      self.batch_key)
                            for x in batch:
                                sched.on_task_start(wid, x)
                        else:
                            sched.on_task_start(wid, t)
                        sched.telemetry.emit(
                            "task_claimed",
                            task_ids=tuple(x.task_id for x in batch)
                            if batch is not None else (t.task_id,),
                            worker=wid)
                        if prefetcher is not None:
                            # snapshot the next wave's tasks under the
                            # lock; their fetches go in flight while THIS
                            # wave executes (thesis §3.5 pipeline)
                            upcoming = list(itertools.islice(
                                itertools.chain(sched.queues[wid],
                                                sched.backlog),
                                prefetcher.lookahead()))
                if t is None:
                    with lock:
                        if sched.done():
                            return
                        # lease sweep while idle: requeue claims whose
                        # lease lapsed (a peer died without reporting)
                        sched.reclaim_expired()
                    time.sleep(1e-4)
                    continue
                claimed = batch if batch is not None else [t]
                try:
                    if self.crash_hook is not None:
                        self.crash_hook(wid)
                    t0 = time.perf_counter()
                    if prefetcher is not None:
                        # admit() drops cache-resident tasks: with
                        # cache-aware ranking they sort FIRST in the
                        # backlog, so the peeked look-ahead would be
                        # exactly the tasks that need no fetch (§14)
                        prefetcher.prefetch(
                            [(x.task_id, lambda _x=x: self.fetch(_x))
                             for x in upcoming if prefetcher.admit(x)])
                        for x in claimed:
                            prefetcher.ensure(
                                x.task_id, lambda _x=x: self.fetch(_x))
                    elif self.fetch is not None:
                        for x in claimed:
                            self.fetch(x)
                    t1 = time.perf_counter()
                    if batch is not None:
                        values = self.run_batch(batch)
                    else:
                        values = [self.run_task(t)]
                    t2 = time.perf_counter()
                except rec.WorkerCrash:
                    # this worker "died" mid-task: reclaim its whole
                    # claimed wave and exit the thread — the supervisor
                    # respawns it under the same id (DESIGN.md §12)
                    with lock:
                        sched.on_worker_crash(wid)
                    return
                except BaseException as e:     # noqa: BLE001
                    if (getattr(e, "permanent", False)
                            and self.stopper is not None):
                        # graceful degradation: this wave's data is
                        # permanently gone, but the job is epsilon-
                        # capable — drop the lost tasks, latch the stop
                        # at the achieved CI, and drain what's in flight
                        with lock:
                            sched.on_tasks_lost(wid, claimed)
                            self.stopper.force_stop(f"degraded: {e}")
                            sched.cancel_pending()
                        continue
                    if getattr(e, "permanent", False):
                        # exact job: fail with a structured partial-
                        # result report instead of a bare traceback
                        with lock:
                            sched.on_tasks_lost(wid, claimed)
                            e = rec.DegradedJobError(
                                f"job degraded: {e}", reason=str(e),
                                n_tasks=len(tasks),
                                completed=len(sched._completed),
                                completed_ids=sched._completed)
                            errors.append(e)
                        return
                    with lock:
                        errors.append(e)
                    return
                fetch_each = (t1 - t0) / len(claimed)
                exec_each = (t2 - t1) / len(claimed)
                if prefetcher is not None:
                    prefetcher.observe_exec(exec_each)
                with lock:
                    for x, value in zip(claimed, values):
                        res = TaskResult(x.task_id, wid, t0, fetch_each,
                                         exec_each, value)
                        results.append(res)
                        sched.on_task_complete(res)
                    # wave-settlement stopping check (DESIGN.md §10):
                    # once the estimate has converged, drop the ready
                    # work; peers' in-flight waves settle and done()
                    # flips when the last one lands
                    if (self.stopper is not None
                            and self.stopper.should_stop()):
                        sched.cancel_pending()

        sched.initial_assignments()
        threads: Dict[int, threading.Thread] = {
            w: threading.Thread(target=worker_loop, args=(w,))
            for w in range(self.n_workers)}
        respawns = {w: 0 for w in range(self.n_workers)}
        for th in threads.values():
            th.start()
        # supervision loop: join with a timeout and respawn dead worker
        # threads while the job is unfinished — a thread that exits
        # before done() is a crash (normal exits only happen at done()
        # or after parking an error), so its claims were (or are now)
        # reclaimed and a fresh thread under the same id picks them up
        while True:
            any_alive = False
            for w, th in list(threads.items()):
                th.join(0.02)
                if th.is_alive():
                    any_alive = True
                    continue
                with lock:
                    finished = bool(errors) or sched.done()
                if finished:
                    continue
                if respawns[w] < self.max_respawns:
                    respawns[w] += 1
                    self.worker_respawns += 1
                    sched.telemetry.emit("worker_respawn", worker=w,
                                         respawn_no=respawns[w])
                    nth = threading.Thread(target=worker_loop, args=(w,))
                    threads[w] = nth
                    nth.start()
                    any_alive = True
                else:
                    # respawn budget exhausted: reclaim (idempotent) and
                    # shrink the pool — survivors absorb the work
                    with lock:
                        sched.on_worker_crash(w, respawn=False)
            if not any_alive:
                break
        if errors:
            raise errors[0]
        if not sched.done() and (self.stopper is None
                                 or not self.stopper.stopped):
            raise JobFailure(
                "job incomplete: every worker exhausted its respawn "
                "budget")
        return results
