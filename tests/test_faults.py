"""Fault-injection framework + recovery-layer tests (DESIGN.md §12):
seeded FaultPlan determinism, lease-based reclamation in both
schedulers, worker-crash respawn bit-identity, probe-driven datastore
auto-revival, the unified RetryPolicy, and checkpoint error surfacing.
"""

import os
import threading
import time

import numpy as np
import pytest

from repro.core import recovery as rec
from repro.core.datastore import (
    DEGRADED,
    DOWN,
    HEALTHY,
    DataNodeError,
    ReplicatedDataStore,
    ReplicationPolicy,
)
from repro.core.scheduler import (
    MultiJobConfig,
    MultiJobScheduler,
    SchedulerConfig,
    Task,
    TaskResult,
    ThreadedRunner,
    TwoPhaseScheduler,
)
from repro.platform.faults import (
    FaultEvent,
    FaultInjector,
    FaultPlan,
    InjectedCrash,
)


def mk_tasks(n, size=1.0):
    return [Task(i, (i,), size) for i in range(n)]


# ---------------------------------------------------------------------------
# FaultPlan / FaultInjector
# ---------------------------------------------------------------------------


def test_fault_plan_from_seed_is_deterministic():
    kw = dict(n_workers=4, n_nodes=3, n_tasks=16,
              worker_crashes=2, node_kills=1, latency_spikes=1,
              revive_after=2)
    a = FaultPlan.from_seed(7, **kw)
    b = FaultPlan.from_seed(7, **kw)
    assert a.events == b.events
    c = FaultPlan.from_seed(8, **kw)
    assert a.events != c.events


def test_fault_event_rejects_unknown_kind():
    with pytest.raises(ValueError):
        FaultEvent(kind="meteor_strike")


def test_node_event_fires_at_exact_completion_count():
    plan = FaultPlan(events=[
        FaultEvent(kind="node_kill", target=0, at_completions=3)])
    inj = FaultInjector(plan)
    store = ReplicatedDataStore(2, seed=0)
    store.put_all({0: np.zeros(4, dtype=np.float32)})
    inj.attach_store(store)
    emit = inj.wrap_emit(None)
    emit(0, None)
    emit(1, None)
    assert inj.fired == []
    emit(2, None)                       # third completion: due
    assert [e.kind for e in inj.fired] == ["node_kill"]
    assert store.node_states()[0] == DOWN


def test_worker_tick_raises_once_at_kth_claim():
    plan = FaultPlan(events=[
        FaultEvent(kind="worker_crash", target=1, at_claims=2)])
    inj = FaultInjector(plan)
    inj.worker_tick(0)                  # other worker: never fires
    inj.worker_tick(1)                  # claim 1 of target: not yet
    with pytest.raises(rec.WorkerCrash):
        inj.worker_tick(1)              # claim 2: fires
    inj.worker_tick(1)                  # once only — respawned id is safe
    assert len(inj.fired) == 1


def test_checkpoint_tick_raises_once_at_kth_save():
    plan = FaultPlan(events=[
        FaultEvent(kind="checkpoint_crash", at_saves=2)])
    inj = FaultInjector(plan)
    inj.checkpoint_tick()
    with pytest.raises(InjectedCrash):
        inj.checkpoint_tick()
    inj.checkpoint_tick()               # fired state is per-event
    assert inj.stats()["events_pending"] == 0.0


def test_node_latency_spike_and_revive_restore_latency_model():
    plan = FaultPlan(events=[
        FaultEvent(kind="node_latency", target=0, at_completions=1,
                   factor=4.0),
        FaultEvent(kind="node_revive", target=0, at_completions=2)])
    inj = FaultInjector(plan)
    store = ReplicatedDataStore(2, latency=lambda nbytes: 1e-4, seed=0)
    inj.attach_store(store)
    orig = store.nodes[0].latency
    inj.on_progress(1)
    assert store.nodes[0].latency(100) == pytest.approx(4 * orig(100))
    inj.on_progress(1)
    assert store.nodes[0].latency is orig


# ---------------------------------------------------------------------------
# TwoPhaseScheduler: crash + lease reclamation
# ---------------------------------------------------------------------------


def test_two_phase_worker_crash_requeues_claims():
    sched = TwoPhaseScheduler(2, mk_tasks(6))
    sched.initial_assignments()
    t = sched.on_worker_idle(0)
    sched.on_task_start(0, t)
    before = sched.queue_depth()
    lost = sched.on_worker_crash(0)
    assert [x.task_id for x in lost] == [t.task_id]
    assert sched.worker_crashes == 1
    assert sched.reclaimed_tasks == 1
    assert sched.queue_depth() >= before  # claim is back in the queues
    # the requeued copy is claimable again and completes the job path
    t2 = sched.on_worker_idle(1)
    assert t2 is not None


def test_two_phase_lease_expiry_requeues_and_dedups():
    cfg = SchedulerConfig(lease_seconds=0.01)
    sched = TwoPhaseScheduler(2, mk_tasks(4), cfg)
    sched.initial_assignments()
    t = sched.on_worker_idle(0)
    sched.on_task_start(0, t, now=0.0)
    expired = sched.reclaim_expired(now=0.005)
    assert expired == []                # lease still live
    expired = sched.reclaim_expired(now=0.02)
    assert [x.task_id for x in expired] == [t.task_id]
    # the original still settles: first completion wins, the duplicate
    # never double-counts
    sched.on_task_complete(TaskResult(t.task_id, 0, 0.0, 0.0, 0.01))
    assert t.task_id in sched._completed
    # reclaim is idempotent — the settled task's lease is gone
    assert sched.reclaim_expired(now=1.0) == []


def test_two_phase_crash_without_respawn_shrinks_pool():
    sched = TwoPhaseScheduler(2, mk_tasks(4))
    sched.initial_assignments()
    t = sched.on_worker_idle(0)
    sched.on_task_start(0, t)
    sched.on_worker_crash(0, respawn=False)
    # the dead worker never gets new work; the survivor still drains
    assert sched.on_worker_idle(1) is not None


# ---------------------------------------------------------------------------
# MultiJobScheduler: dead workers, leases, lost tasks
# ---------------------------------------------------------------------------


def _mjs(n_tasks=6, lease=None):
    sched = MultiJobScheduler(2, MultiJobConfig(lease_seconds=lease))
    # uniform fuse key so one claim can batch several tasks
    sched.add_job(0, mk_tasks(n_tasks), cap=4, fuse_key=lambda t: 0)
    return sched


def test_multi_job_on_worker_dead_requeues():
    sched = _mjs()
    batch = sched.claim(now=0.0, max_n=2, worker=0)
    assert len(batch) == 2
    lost = sched.on_worker_dead(0)
    assert len(lost) == 2
    job = sched.jobs[0]
    assert job.inflight == 0
    # requeued at the front, claimable by a peer
    again = sched.claim(now=0.0, max_n=2, worker=1)
    assert {t.task_id for _, t in again} == {t.task_id for _, t in batch}
    assert sched.on_worker_dead(0) == []  # idempotent


def test_multi_job_lease_expiry_requeues_then_dedups():
    sched = _mjs(lease=0.01)
    (job, task), = sched.claim(now=0.0, max_n=1, worker=0)
    assert sched.reclaim_expired(now=0.005) == []
    expired = sched.reclaim_expired(now=0.02)
    assert [(j, t.task_id) for j, t in expired] == [(job.job_id,
                                                     task.task_id)]
    # original settles first; the requeued duplicate is filtered at
    # claim time and the job still finishes exactly once
    sched.on_task_complete(job.job_id, 0.01, task.task_id, worker=0)
    assert task.task_id in job.completed_ids
    assert job.completed == 1


def test_multi_job_on_task_lost_shrinks_job():
    sched = _mjs(n_tasks=3)
    (job, task), = sched.claim(now=0.0, max_n=1, worker=0)
    finished = sched.on_task_lost(job.job_id, task.task_id, worker=0)
    assert not finished                  # two tasks still pending
    assert job.n_tasks == 2
    assert job.inflight == 0


# ---------------------------------------------------------------------------
# ThreadedRunner: crash respawn is bit-identical
# ---------------------------------------------------------------------------


def _task_value(t):
    time.sleep(0.003)       # keep every worker claiming for a while
    return t.task_id * 10 + 1


def _run_threaded(crash_hook=None, max_respawns=2, n=12):
    runner = ThreadedRunner(
        3, run_task=_task_value,
        cfg=SchedulerConfig(lease_seconds=0.5),
        crash_hook=crash_hook, max_respawns=max_respawns)
    results = runner.run_job(mk_tasks(n))
    return {r.task_id: r.value for r in results}, runner


def test_threaded_runner_crash_respawn_bit_identical():
    clean, _ = _run_threaded()
    inj = FaultInjector(FaultPlan(events=[
        FaultEvent(kind="worker_crash", target=1, at_claims=1)]))
    faulty, runner = _run_threaded(crash_hook=inj.worker_tick)
    assert [e.kind for e in inj.fired] == ["worker_crash"]
    assert runner.worker_respawns == 1
    assert faulty == clean


def test_threaded_runner_survives_multiple_crashes():
    inj = FaultInjector(FaultPlan(events=[
        FaultEvent(kind="worker_crash", target=0, at_claims=1),
        FaultEvent(kind="worker_crash", target=2, at_claims=1)]))
    clean, _ = _run_threaded()
    faulty, runner = _run_threaded(crash_hook=inj.worker_tick)
    assert runner.worker_respawns == 2
    assert faulty == clean


# ---------------------------------------------------------------------------
# Datastore: probe-driven auto-revival
# ---------------------------------------------------------------------------


def _down_node(store, nid=0):
    """Drive node ``nid`` DOWN through the failure detector (arming the
    auto-revival probe — unlike administrative mark_down)."""
    store.nodes[nid].failing = True
    for _ in range(store.policy.max_consecutive_failures):
        for sid in store._samples:
            try:
                store.fetch(sid)
            except DataNodeError:
                pass
            if store.node_states()[nid] == DOWN:
                return
    assert store.node_states()[nid] == DOWN


def test_auto_revival_probe_restores_recovered_node():
    policy = ReplicationPolicy(probe_interval=0.01)
    store = ReplicatedDataStore(2, policy=policy, seed=0)
    store.put_all({i: np.zeros(8, dtype=np.float32) for i in range(4)})
    _down_node(store, 0)
    node = store.nodes[0]
    assert node.auto_probe and node.next_probe_at is not None
    node.failing = False                # the node "comes back"
    time.sleep(0.02)
    store.fetch(0)                      # fetch path runs the due probe
    # back in service: revive() sets HEALTHY, but the probe's own
    # latency seeds the fresh EMA and on a loaded machine can land
    # above the peer-median outlier threshold — DEGRADED still serves
    # claims, only DOWN is out of rotation
    assert store.node_states()[0] in (HEALTHY, DEGRADED)
    assert not node.auto_probe          # probe disarmed after revival


def test_failed_probe_backs_off_and_leaves_node_down():
    policy = ReplicationPolicy(probe_interval=0.01,
                               probe_backoff_factor=2.0)
    store = ReplicatedDataStore(2, policy=policy, seed=0)
    store.put_all({i: np.zeros(8, dtype=np.float32) for i in range(4)})
    _down_node(store, 0)
    node = store.nodes[0]
    failures_before = node.failures
    time.sleep(0.02)
    store.fetch(0)                      # probe runs, node still failing
    assert store.node_states()[0] == DOWN
    assert node.probe_interval == pytest.approx(0.02)
    # probes are health checks, not serving failures: the availability
    # counters don't move (pinned by the balanced-scheduling tests too)
    assert node.failures == failures_before


def test_administrative_mark_down_is_sticky():
    policy = ReplicationPolicy(probe_interval=0.01)
    store = ReplicatedDataStore(2, policy=policy, seed=0)
    store.put_all({i: np.zeros(8, dtype=np.float32) for i in range(4)})
    store.mark_down(0)
    assert store.nodes[0].auto_probe is False
    time.sleep(0.02)
    store.fetch(0)
    assert store.node_states()[0] == DOWN


# ---------------------------------------------------------------------------
# RetryPolicy / RetryBudget
# ---------------------------------------------------------------------------


def test_retry_policy_retries_transient_then_succeeds():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("flake")
        return "ok"

    policy = rec.RetryPolicy(max_attempts=3)
    assert policy.call(flaky) == "ok"
    assert len(calls) == 3


def test_retry_policy_fails_fast_on_permanent():
    calls = []

    def broken():
        calls.append(1)
        raise KeyError("missing")

    with pytest.raises(KeyError):
        rec.RetryPolicy(max_attempts=5).call(broken)
    assert len(calls) == 1

    def tagged():
        calls.append(1)
        e = OSError("replicas exhausted")
        e.permanent = True
        raise e

    calls.clear()
    with pytest.raises(OSError):
        rec.RetryPolicy(max_attempts=5).call(tagged)
    assert len(calls) == 1


def test_retry_budget_exhaustion_stops_retrying():
    budget = rec.RetryBudget(limit=1)
    calls = []

    def flaky():
        calls.append(1)
        raise OSError("flake")

    with pytest.raises(OSError):
        rec.RetryPolicy(max_attempts=10).call(flaky, budget=budget)
    assert len(calls) == 2              # 1 try + 1 budgeted retry
    assert budget.spent == 1


def test_retry_delay_backoff_and_seeded_jitter():
    policy = rec.RetryPolicy(max_attempts=4, base_delay=0.1,
                             backoff_factor=2.0, max_delay=0.3)
    assert policy.delay(1) == pytest.approx(0.1)
    assert policy.delay(2) == pytest.approx(0.2)
    assert policy.delay(3) == pytest.approx(0.3)   # capped
    jittered = rec.RetryPolicy(max_attempts=4, base_delay=0.1,
                               jitter=0.5)
    import random
    a = jittered.delay(1, random.Random(3))
    b = jittered.delay(1, random.Random(3))
    assert a == b                       # deterministic for a seeded rng
    assert 0.05 <= a <= 0.15


def test_datastore_replica_exhaustion_is_permanent():
    store = ReplicatedDataStore(2, seed=0)
    store.put_all({0: np.zeros(4, dtype=np.float32)})
    for n in store.nodes:
        n.failing = True
    with pytest.raises(DataNodeError) as ei:
        store.fetch(0)
    assert rec.is_permanent(ei.value)


# ---------------------------------------------------------------------------
# CheckpointManager: async error surfacing
# ---------------------------------------------------------------------------


def test_checkpoint_background_error_surfaces_on_wait(tmp_path,
                                                      monkeypatch):
    from repro.checkpoint import manager as mgr_mod
    mgr = mgr_mod.CheckpointManager(str(tmp_path / "ck"))

    def boom(tree):
        raise OSError("disk full")

    monkeypatch.setattr(mgr_mod, "_flatten_with_names", boom)
    mgr.save(0, {"w": np.zeros(3, dtype=np.float32)})
    with pytest.raises(OSError, match="disk full"):
        mgr.wait()
    mgr.wait()                          # error raised once, then cleared


def test_checkpoint_background_error_surfaces_on_next_save(tmp_path,
                                                           monkeypatch):
    from repro.checkpoint import manager as mgr_mod
    mgr = mgr_mod.CheckpointManager(str(tmp_path / "ck"))
    state = {"w": np.zeros(3, dtype=np.float32)}

    def boom(tree):
        raise OSError("disk full")

    monkeypatch.setattr(mgr_mod, "_flatten_with_names", boom)
    mgr.save(0, state)
    with pytest.raises(OSError, match="disk full"):
        mgr.save(1, state)              # next save waits first: surfaces


def test_checkpoint_atomic_rename_keeps_last_good_step(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d)
    mgr.save(0, {"w": np.arange(3, dtype=np.float32)}, blocking=True)
    # a crash mid-write leaves only a .tmp — never a visible step
    os.makedirs(os.path.join(d, "step_00000001.tmp"))
    assert mgr.all_steps() == [0]
    got = mgr.restore_latest()
    np.testing.assert_array_equal(got["['w']"], np.arange(3,
                                                          dtype=np.float32))


# ---------------------------------------------------------------------------
# Satellite regressions: fetch_many failover racing close(); pool
# worker death between claim and settlement
# ---------------------------------------------------------------------------


def test_fetch_many_mid_batch_failover_racing_close():
    store = ReplicatedDataStore(3, seed=0)
    samples = {i: np.full(16, i, dtype=np.float32) for i in range(8)}
    store.put_all(samples)
    store.nodes[1].failing = True       # mid-batch failures every round
    errors = []
    stop = threading.Event()

    def fetcher():
        while not stop.is_set():
            try:
                out = store.fetch_many(list(range(8)))
                for i, a in enumerate(out):
                    assert float(a[0]) == float(i)
            except Exception as e:      # noqa: BLE001
                errors.append(e)
                return

    threads = [threading.Thread(target=fetcher) for _ in range(3)]
    for th in threads:
        th.start()
    for _ in range(30):                 # close() races the in-flight pool
        store.close()
        time.sleep(0.002)
    stop.set()
    for th in threads:
        th.join(timeout=10)
        assert not th.is_alive()
    assert errors == []
    # inflight accounting settled: no claim leaked through the races
    assert all(n.inflight == 0 for n in store.nodes)
    store.close()


def test_service_pool_worker_death_between_claim_and_settlement():
    """A pool worker that dies after claiming (WorkerCrash from the
    crash hook — exactly the claim→settlement window) must not lose the
    job: the monitor respawns the thread and lease/crash reclamation
    requeues the claims, bit-identical to the fault-free run."""
    from repro.core import subsample as ss
    from repro.data.synthetic import NetflixSpec, netflix_dataset
    from repro.platform import PlatformSpec
    from repro.platform.service import PlatformService

    samples, months = netflix_dataset(
        NetflixSpec(n_movies=12, mean_ratings=512))
    spec = PlatformSpec(platform="BTS", n_workers=2, backend="threaded",
                        knee_bytes=4 * 1024 * 4, seed=5,
                        lease_seconds=0.5)

    def run(injector=None):
        svc = PlatformService(spec, fault_injector=injector)
        with svc:
            h = svc.register_dataset(samples, months)
            t = svc.submit(h, ss.NETFLIX_HIGH)
            r = t.result(timeout=120)
        return r, svc

    clean, _ = run()
    inj = FaultInjector(FaultPlan(events=[
        FaultEvent(kind="worker_crash", target=0, at_claims=1)]))
    faulty, svc = run(injector=inj)
    assert [e.kind for e in inj.fired] == ["worker_crash"]
    assert svc._pool.worker_respawns == 1
    for k in clean:
        np.testing.assert_array_equal(np.asarray(clean[k]),
                                      np.asarray(faulty[k]))


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_service_pool_monitor_respawns_hard_thread_death():
    """A worker thread that dies WITHOUT self-reporting (an unexpected
    exception, not WorkerCrash) is detected by the supervision monitor,
    its claims reclaimed via on_worker_dead, and the thread respawned."""
    from repro.core import subsample as ss
    from repro.data.synthetic import NetflixSpec, netflix_dataset
    from repro.platform import PlatformSpec
    from repro.platform.service import PlatformService

    samples, months = netflix_dataset(
        NetflixSpec(n_movies=12, mean_ratings=512))
    spec = PlatformSpec(platform="BTS", n_workers=2, backend="threaded",
                        knee_bytes=4 * 1024 * 4, seed=5,
                        lease_seconds=0.5)
    died = threading.Event()

    def hard_death(wid):
        if wid == 0 and not died.is_set():
            died.set()
            raise RuntimeError("segfault stand-in: thread dies silently")

    with PlatformService(spec) as ref_svc:
        h = ref_svc.register_dataset(samples, months)
        clean = ref_svc.submit(h, ss.NETFLIX_HIGH).result(timeout=120)

    class HookInjector:
        """Minimal injector stand-in: only the crash hook matters."""

        def __init__(self):
            self.fired = []

        def worker_tick(self, wid):
            hard_death(wid)

        def wrap_emit(self, emit):
            return emit

        def attach_store(self, store):
            pass

    svc = PlatformService(spec, fault_injector=HookInjector())
    with svc:
        h = svc.register_dataset(samples, months)
        t = svc.submit(h, ss.NETFLIX_HIGH)
        r = t.result(timeout=120)
    assert died.is_set()
    assert svc._pool.worker_respawns == 1
    for k in clean:
        np.testing.assert_array_equal(np.asarray(clean[k]),
                                      np.asarray(r[k]))
