"""Prefetch pipeline with dynamic look-ahead (thesis §1.1.4, §3.5).

While a task executes, data for the next ``k`` queued tasks is fetched in
the background; ``k`` is decided dynamically from the ratio of average
fetch time to average execution time (exactly the scheduler's
``queue_depth`` rule).  This is also the host-side input pipeline for LM
training: kneepoint-sized microbatch shards are prefetched ahead of the
device step (double/triple buffering).
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Any, Callable, Iterator, Optional


class PrefetchPipeline:
    """Wrap a producer iterator with a background prefetch thread whose
    buffer depth adapts to measured fetch/consume times."""

    def __init__(self, producer: Iterator[Any], *,
                 min_depth: int = 2, max_depth: int = 64):
        self._producer = producer
        self._min_depth = min_depth
        self._max_depth = max_depth
        self._buf: collections.deque = collections.deque()
        self._cv = threading.Condition()
        self._done = False
        self._fetch_ema: Optional[float] = None
        self._consume_ema: Optional[float] = None
        self._last_take: Optional[float] = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def depth(self) -> int:
        """k = ceil(fetch/exec) + 1, clamped (the paper's dynamic k)."""
        if not self._consume_ema or not self._fetch_ema:
            return self._min_depth
        k = int(self._fetch_ema / max(self._consume_ema, 1e-9)) + 1
        return max(self._min_depth, min(self._max_depth, k))

    def _run(self) -> None:
        try:
            for item in self._producer:
                t0 = time.perf_counter()
                with self._cv:
                    while len(self._buf) >= self.depth() and not self._done:
                        self._cv.wait(timeout=0.05)
                    if self._done:
                        return
                    self._buf.append(item)
                    self._cv.notify_all()
                took = time.perf_counter() - t0
                a = 0.3
                self._fetch_ema = (took if self._fetch_ema is None
                                   else (1 - a) * self._fetch_ema + a * took)
        finally:
            with self._cv:
                self._done = True
                self._cv.notify_all()

    def __iter__(self):
        return self

    def __next__(self):
        now = time.perf_counter()
        if self._last_take is not None:
            gap = now - self._last_take
            a = 0.3
            self._consume_ema = (gap if self._consume_ema is None
                                 else (1 - a) * self._consume_ema + a * gap)
        with self._cv:
            while not self._buf and not self._done:
                self._cv.wait(timeout=0.05)
            if self._buf:
                item = self._buf.popleft()
                self._cv.notify_all()
                self._last_take = time.perf_counter()
                return item
        raise StopIteration

    def close(self) -> None:
        with self._cv:
            self._done = True
            self._cv.notify_all()
