"""Kneepoint algorithm tests (thesis Fig 2/3 behaviour) + properties."""

import numpy as np

from tests._hypothesis_compat import given, settings, st

from repro.core.kneepoint import (
    SANDY_BRIDGE_HIERARCHY,
    TPU_V5E_HIERARCHY,
    CurvePoint,
    amat_curve,
    find_kneepoint,
    pack_tasks,
)


def test_flat_then_step_curve_knees_before_step():
    # classic Fig 2 shape: flat miss rate, then a sharp step at 2.5MB
    sizes = [0.5, 1.0, 2.0, 2.5, 4.0, 8.0, 16.0, 25.0]
    costs = [1.0, 1.0, 1.01, 1.01, 3.0, 6.0, 12.0, 35.0]
    res = find_kneepoint([CurvePoint(s, c) for s, c in zip(sizes, costs)])
    assert res.task_size == 2.5, res


def test_monotone_flat_curve_prefers_largest_task():
    pts = [CurvePoint(s, 1.0) for s in (1, 2, 4, 8)]
    res = find_kneepoint(pts)
    assert res.task_size == 8


def test_amat_curve_has_knee_at_cache_capacity():
    ws = np.geomspace(2**18, 2**26, 24)
    pts = amat_curve(ws, SANDY_BRIDGE_HIERARCHY)
    res = find_kneepoint(pts, tolerance=0.3)
    # knee must sit at or below the L2-ish capacity region (≤ ~4MB)
    assert res.task_size <= 4 * 2**20


def test_amat_curve_tpu_hierarchy_knee_below_vmem_scale():
    ws = np.geomspace(2**20, 2**31, 24)
    pts = amat_curve(ws, TPU_V5E_HIERARCHY)
    res = find_kneepoint(pts, tolerance=0.3)
    assert res.task_size <= 64 * 2**20


@given(st.lists(st.integers(min_value=1, max_value=10_000),
                min_size=1, max_size=200),
       st.floats(min_value=1.0, max_value=50_000.0,
                 allow_nan=False, allow_infinity=False))
@settings(max_examples=100, deadline=None)
def test_pack_tasks_partition_property(sizes, knee):
    """Packing must be a partition: every sample exactly once, order kept."""
    tasks = pack_tasks(sizes, knee)
    flat = [i for t in tasks for i in t]
    assert flat == list(range(len(sizes)))
    # no task exceeds the knee unless it is a singleton outlier
    for t in tasks:
        total = sum(sizes[i] for i in t)
        assert total <= knee or len(t) == 1


@given(st.lists(
    st.tuples(st.floats(min_value=0.1, max_value=1e6, allow_nan=False),
              st.floats(min_value=0.0, max_value=1e6, allow_nan=False)),
    min_size=2, max_size=50))
@settings(max_examples=100, deadline=None)
def test_kneepoint_always_returns_a_measured_size(points):
    # dedupe sizes to keep the curve a function
    seen = {}
    for s, c in points:
        seen[s] = c
    if len(seen) < 2:
        return
    pts = [CurvePoint(s, c) for s, c in seen.items()]
    res = find_kneepoint(pts)
    assert any(p.task_size == res.task_size for p in pts)
    assert 0 <= res.index < len(pts)
