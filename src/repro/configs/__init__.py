"""One module per assigned architecture (+ the paper's own workload).

Use :func:`repro.config.get_config` to resolve ``--arch`` ids.
"""
