"""Elastic scaling controller for the tiny-task platform.

Workers may join or leave *between jobs* freely (the scheduler is
constructed per job) and leave *during* a job under the recovery model
(job-level restart on survivors, or task-level reclamation).  This module
adds the control loop the thesis implies in §4.2.3: scale the worker pool
per job to the SLO using measured throughput profiles, and keep a warm
standby so a failure mid-job restarts at full width.

For training jobs, elasticity is realized at the job boundary: the
checkpoint is mesh-agnostic (per-leaf full arrays in this single-process
build; sharded re-load re-shards on restore), so a restart may use a
different data-parallel width — the resume path in ``repro.train.loop``
demonstrates this with a smaller/larger batch as long as tokens/step is
preserved.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Sequence

from repro.core.scheduler import SimParams, SimWorker, simulate_job
from repro.core.slo import ScaleDecision, choose_cores


@dataclasses.dataclass
class PoolEvent:
    time: float
    action: str              # "grow" | "shrink" | "restart"
    size: int
    reason: str


class ElasticWorkerPool:
    """Tracks desired vs available workers and produces scale decisions
    per submitted job."""

    def __init__(self, core_options: Sequence[int],
                 throughput: Callable[[int, float], float],
                 startup: Callable[[int], float]):
        self.core_options = sorted(core_options)
        self.throughput = throughput
        self.startup = startup
        self.size = self.core_options[0]
        self.events: List[PoolEvent] = []

    def plan_job(self, job_bytes: float, slo_seconds: float
                 ) -> ScaleDecision:
        decision = choose_cores(
            self.core_options,
            throughput=lambda c: self.throughput(c, job_bytes),
            startup=self.startup,
            slo_seconds=slo_seconds)
        if decision.cores != self.size:
            action = "grow" if decision.cores > self.size else "shrink"
            self.events.append(PoolEvent(time.time(), action,
                                         decision.cores, decision.reason))
            self.size = decision.cores
        return decision

    def on_failure(self, lost: int) -> int:
        """A node died mid-job: job-level recovery restarts on survivors;
        the pool immediately requests a replacement for the next job."""
        self.size = max(1, self.size - lost)
        self.events.append(PoolEvent(time.time(), "restart", self.size,
                                     f"lost {lost} worker(s)"))
        return self.size


def demo_elastic_run(job_sizes: Sequence[float], slo_seconds: float,
                     per_byte_cost: float = 1e-8) -> Dict[str, object]:
    """Simulated elastic session: plan + run each job, inject one failure."""
    def tp(cores: int, job_bytes: float) -> float:
        return cores * 1e8                     # 100 MB/s/core steady state

    pool = ElasticWorkerPool((4, 8, 16, 32), tp,
                             startup=lambda c: 0.05 + 0.002 * c)
    reports = []
    for i, size in enumerate(job_sizes):
        decision = pool.plan_job(size, slo_seconds)
        from repro.core.scheduler import SchedulerConfig, Task
        n_tasks = max(8, int(size / 2**20))
        tasks = [Task(t, (t,), size / n_tasks) for t in range(n_tasks)]
        workers = [SimWorker(w, fail_at=(0.01 if (i == 1 and w == 0)
                                         else None))
                   for w in range(decision.cores)]
        out = simulate_job(
            tasks, workers,
            SimParams(exec_time=lambda t: t.size_bytes * per_byte_cost,
                      fetch_time=lambda t: 0.0,
                      startup_time=pool.startup(decision.cores)),
            SchedulerConfig(recovery="job"))
        if out.restarts:
            pool.on_failure(1)
        reports.append({"job": i, "cores": decision.cores,
                        "makespan": out.makespan,
                        "restarts": out.restarts,
                        "met_slo": out.makespan <= slo_seconds})
    return {"reports": reports, "events": pool.events}
