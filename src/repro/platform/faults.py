"""Deterministic, seeded fault injection (DESIGN.md §12).

The nightly ``--chaos`` pass agitates the data plane from a free-running
thread — good for soak, useless as a gate: no two runs inject the same
faults.  This module replaces the lottery with a **plan**: a
:class:`FaultPlan` is a list of :class:`FaultEvent` s with exact trigger
points counted in *logical* progress units —

* **node events** fire when the job's N-th task completion is observed
  (``at_completions``), mutating the attached
  :class:`~repro.core.datastore.ReplicatedDataStore`;
* **worker crashes** fire when worker ``target`` makes its K-th claim
  (``at_claims``), raising :class:`~repro.core.recovery.WorkerCrash`
  inside that worker's loop — mid-task, after the claim, before
  settlement: exactly the window lease-based reclamation covers;
* **checkpoint crashes** fire on the K-th checkpoint save
  (``at_saves``), raising :class:`InjectedCrash` to simulate the process
  dying mid-save (the atomic tmp+rename protocol must leave the last
  good checkpoint restorable).

Trigger points are logical, so a plan is reproducible across machines
and backends; *which* task is the N-th completion may differ run to run,
but the recovery layers (lease reclamation + first-completion-wins dedup
+ the fixed reduce tree) guarantee the job RESULT is bit-identical to
the fault-free run regardless — that is the property ``bench_faults``
gates.
"""

from __future__ import annotations

import dataclasses
import random
import threading
from typing import Any, Callable, Dict, List, Optional

from repro.core import recovery as rec

NODE_KINDS = ("node_latency", "node_error", "node_kill", "node_revive")
KINDS = NODE_KINDS + ("worker_crash", "checkpoint_crash")


class InjectedCrash(RuntimeError):
    """A planned checkpoint-write crash: simulates the process dying
    mid-save.  Propagates out of the run like a real crash would; the
    checkpoint directory must still hold the last committed step."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One planned fault.  ``target`` is a data-node id for node events,
    a worker id for ``worker_crash``, ignored for ``checkpoint_crash``.
    Exactly one of the ``at_*`` trigger points applies per kind."""

    kind: str
    target: int = 0
    at_completions: int = 0     # node events: N-th observed completion
    at_claims: int = 0          # worker_crash: target's K-th claim
    at_saves: int = 0           # checkpoint_crash: K-th checkpoint save
    factor: float = 1.0         # node_latency: latency multiplier

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose one of {KINDS}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, reusable fault schedule.  Build one explicitly or
    draw a seeded random plan with :meth:`from_seed` — either way two
    runs under the same plan inject the same faults at the same logical
    points."""

    events: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    @classmethod
    def from_seed(cls, seed: int, *, n_workers: int, n_nodes: int,
                  n_tasks: int, worker_crashes: int = 1,
                  node_kills: int = 1, latency_spikes: int = 1,
                  revive_after: Optional[int] = None) -> "FaultPlan":
        """Seeded chaos: crash ``worker_crashes`` distinct workers at
        random claim counts, kill ``node_kills`` distinct nodes at random
        completion points (revived ``revive_after`` completions later
        when given), and spike latency on ``latency_spikes`` nodes."""
        rng = random.Random(seed)
        events: List[FaultEvent] = []
        span = max(n_tasks, 2)
        for wid in rng.sample(range(n_workers),
                              min(worker_crashes, n_workers)):
            events.append(FaultEvent(
                "worker_crash", target=wid,
                at_claims=rng.randint(1, max(1, span // n_workers))))
        for nid in rng.sample(range(n_nodes), min(node_kills, n_nodes)):
            at = rng.randint(1, max(1, span // 2))
            events.append(FaultEvent("node_kill", target=nid,
                                     at_completions=at))
            if revive_after is not None:
                events.append(FaultEvent(
                    "node_revive", target=nid,
                    at_completions=at + revive_after))
        for nid in rng.sample(range(n_nodes),
                              min(latency_spikes, n_nodes)):
            events.append(FaultEvent(
                "node_latency", target=nid,
                at_completions=rng.randint(1, max(1, span // 2)),
                factor=rng.uniform(2.0, 8.0)))
        return cls(tuple(events))


class FaultInjector:
    """Drives one run's :class:`FaultPlan`.  One injector per run — it
    holds fired-state; the plan itself is reusable.

    Hooks (all thread-safe):

    * :meth:`attach_store` — give node events their target store;
    * :meth:`on_progress` — observe task completions (drivers wrap their
      ``emit`` with :meth:`wrap_emit`); due node events fire inline;
    * :meth:`worker_tick` — called by runner/pool workers right after a
      claim; raises :class:`~repro.core.recovery.WorkerCrash` when a
      planned crash is due (once per event — the respawned worker reuses
      the id and must not crash again);
    * :meth:`checkpoint_tick` — called by the checkpointer before each
      save; raises :class:`InjectedCrash` when due.
    """

    def __init__(self, plan: FaultPlan, store: Optional[Any] = None):
        self.plan = plan
        self._store = store
        # optional repro.platform.telemetry.TelemetryBus the driver or
        # service attaches; every fired event emits "fault_fired"
        self.telemetry = None
        self._lock = threading.Lock()
        self._completions = 0
        self._claims: Dict[int, int] = {}
        self._saves = 0
        self._fired: List[FaultEvent] = []
        self._pending: List[FaultEvent] = list(plan.events)
        # original latency models of spiked nodes (node_revive restores)
        self._orig_latency: Dict[int, Callable[[int], float]] = {}

    def attach_store(self, store: Any) -> None:
        self._store = store

    def _emit_fired(self, e: FaultEvent) -> None:
        bus = self.telemetry
        if bus is not None:
            bus.emit("fault_fired", fault_kind=e.kind, target=e.target,
                     at_completions=e.at_completions,
                     at_claims=e.at_claims, at_saves=e.at_saves)

    @property
    def fired(self) -> List[FaultEvent]:
        with self._lock:
            return list(self._fired)

    # -- node events (logical completion clock) ---------------------------
    def on_progress(self, n: int = 1) -> None:
        with self._lock:
            self._completions += n
            due = [e for e in self._pending
                   if e.kind in NODE_KINDS
                   and e.at_completions <= self._completions]
            for e in due:
                self._pending.remove(e)
                self._fired.append(e)
        for e in due:
            self._emit_fired(e)
            self._fire_node_event(e)

    def wrap_emit(self, emit: Optional[Callable[[int, Any], None]]
                  ) -> Callable[[int, Any], None]:
        """Wrap a driver's per-task ``emit`` so every completion ticks
        the logical clock (after the partial is offered — a fault fires
        between completions, never inside one)."""

        def wrapped(task_id: int, partial: Any) -> None:
            if emit is not None:
                emit(task_id, partial)
            self.on_progress(1)

        return wrapped

    def _fire_node_event(self, e: FaultEvent) -> None:
        store = self._store
        if store is None:
            return
        try:
            node = store._node(e.target)
        except KeyError:
            return                      # adaptive sizing removed the node
        if e.kind == "node_latency":
            with self._lock:
                self._orig_latency.setdefault(e.target, node.latency)
            orig = self._orig_latency[e.target]
            node.latency = lambda nbytes: orig(nbytes) * e.factor
        elif e.kind == "node_error":
            node.failing = True
        elif e.kind == "node_kill":
            node.failing = True
            store.mark_down(e.target)
        elif e.kind == "node_revive":
            node.failing = False
            with self._lock:
                orig = self._orig_latency.pop(e.target, None)
            if orig is not None:
                node.latency = orig
            store.revive(e.target)

    # -- worker crashes (per-worker claim clock) --------------------------
    def worker_tick(self, worker: int) -> None:
        fire = None
        with self._lock:
            self._claims[worker] = self._claims.get(worker, 0) + 1
            count = self._claims[worker]
            for e in self._pending:
                if (e.kind == "worker_crash" and e.target == worker
                        and count >= e.at_claims):
                    fire = e
                    break
            if fire is not None:
                self._pending.remove(fire)
                self._fired.append(fire)
        if fire is not None:
            self._emit_fired(fire)
            raise rec.WorkerCrash(
                f"injected crash: worker {worker} at claim "
                f"{self._claims[worker]}")

    # -- checkpoint crashes (save clock) ----------------------------------
    def checkpoint_tick(self) -> None:
        fire = None
        with self._lock:
            self._saves += 1
            for e in self._pending:
                if (e.kind == "checkpoint_crash"
                        and self._saves >= e.at_saves):
                    fire = e
                    break
            if fire is not None:
                self._pending.remove(fire)
                self._fired.append(fire)
        if fire is not None:
            self._emit_fired(fire)
            raise InjectedCrash(
                f"injected crash: checkpoint save {self._saves}")

    def stats(self) -> Dict[str, float]:
        with self._lock:
            return {"events_fired": float(len(self._fired)),
                    "events_pending": float(len(self._pending)),
                    "completions_seen": float(self._completions),
                    "checkpoint_saves_seen": float(self._saves)}
