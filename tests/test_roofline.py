"""Roofline accounting unit tests: HLO collective parsing, scan-correction
extrapolation, hardware terms, and the analytic traffic model."""

import pytest

from repro.config import SHAPES, SINGLE_POD_MESH, get_config
from repro.config.base import TrainConfig
from repro.roofline import (CellCost, collective_bytes, extrapolate,
                            hw, model_flops_per_step, roofline)
from repro.roofline.traffic import memory_traffic

HLO = """
  %ag = bf16[8,1024]{1,0} all-gather(%p0), replica_groups=...
  %ar.1 = f32[256]{0} all-reduce(%x), to_apply=%sum
  %ags = (bf16[8,1024]{1,0}, bf16[8,1024]{1,0}) all-gather-start(%p1)
  %agd = bf16[8,1024]{1,0} all-gather-done(%ags)
  %rs = f32[64,32]{1,0} reduce-scatter(%y), dimensions={0}
  %cp = bf16[4,4]{1,0} collective-permute(%z), source_target_pairs=...
  %a2a = f32[16,16]{1,0} all-to-all(%w), dimensions={0}
  %dot = f32[128,128]{1,0} dot(%a, %b)
"""


def test_collective_parsing_kinds_and_bytes():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 8 * 1024 * 2 + 2 * 8 * 1024 * 2  # ag + start
    assert out["all-reduce"] == 256 * 4
    assert out["reduce-scatter"] == 64 * 32 * 4
    assert out["collective-permute"] == 16 * 2
    assert out["all-to-all"] == 16 * 16 * 4
    # -done is not double counted; dot is not a collective
    assert out["ops"] == 6


def test_extrapolation_math():
    c1 = CellCost(10.0, 100.0, 5.0, 1)    # nonlayer 4 + 1 unit of 6
    c2 = CellCost(16.0, 150.0, 7.0, 2)    # nonlayer + 2 units
    total = extrapolate(c1, c2, n_units=10)
    assert total.flops == pytest.approx(4 + 10 * 6)
    assert total.bytes_accessed == pytest.approx(50 + 10 * 50)
    assert total.coll_bytes == pytest.approx(3 + 10 * 2)


def test_extrapolation_with_microbatches_and_correction():
    c1 = CellCost(10.0, 100.0, 5.0, 1)
    c2 = CellCost(16.0, 150.0, 7.0, 2)
    corr = CellCost(1.0, 10.0, 0.0, 0)
    total = extrapolate(c1, c2, n_units=10, n_repeat=4,
                        per_repeat_correction=corr)
    assert total.flops == pytest.approx(64 * 4 - 3 * 1.0)
    assert total.bytes_accessed == pytest.approx(550 * 4 - 3 * 10.0)


def test_roofline_terms_and_dominance():
    cost = CellCost(flops=hw.PEAK_FLOPS_BF16,          # 1s compute
                    bytes_accessed=hw.HBM_BW / 2,       # 0.5s memory
                    coll_bytes=hw.ICI_LINK_BW / 4,      # 0.25s collective
                    coll_ops=10)
    rt = roofline(cost, chips=256, model_flops=hw.PEAK_FLOPS_BF16 * 128)
    assert rt.dominant == "compute"
    assert rt.compute_s == pytest.approx(1.0)
    assert rt.memory_s == pytest.approx(0.5)
    assert rt.collective_s == pytest.approx(0.25)
    assert rt.useful_ratio == pytest.approx(0.5)


def test_model_flops_dense_vs_moe():
    dense = get_config("deepseek-7b")
    moe = get_config("arctic-480b")
    shape = SHAPES["train_4k"]
    f_dense = model_flops_per_step(dense, shape)
    tokens = shape.global_batch * shape.seq_len
    assert f_dense == pytest.approx(6 * dense.param_count() * tokens)
    f_moe = model_flops_per_step(moe, shape)
    assert f_moe == pytest.approx(6 * moe.active_param_count() * tokens)
    assert f_moe < 6 * moe.param_count() * tokens * 0.1


def test_traffic_model_scales_sanely():
    cfg = get_config("qwen2-72b")
    t_train = memory_traffic(cfg, SHAPES["train_4k"], SINGLE_POD_MESH,
                             n_mb=16, tcfg=TrainConfig())
    t_decode = memory_traffic(cfg, SHAPES["decode_32k"], SINGLE_POD_MESH)
    # decode reads params once; train re-gathers per microbatch + optimizer
    assert t_train > t_decode
    # decode must be dominated by params+cache, of plausible magnitude
    p_read = cfg.param_count() * 2 / SINGLE_POD_MESH.tp_size
    assert t_decode > p_read
    assert t_decode < 20 * p_read


def test_traffic_model_decode_moe_reads_less_than_dense_equivalent():
    moe = get_config("deepseek-moe-16b")
    t = memory_traffic(moe, SHAPES["decode_32k"], SINGLE_POD_MESH)
    full = moe.param_count() * 2 / SINGLE_POD_MESH.tp_size
    active_bound = (SHAPES["decode_32k"].global_batch
                    * moe.active_param_count() * 2 / SINGLE_POD_MESH.tp_size)
    assert t <= full + active_bound + 2**34
