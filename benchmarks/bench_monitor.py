"""SLO monitor, critical path, and root-cause diagnosis (DESIGN.md §15)
— the monitor section of BENCH_platform.json.

Four sections, the ISSUE 10 acceptance gates:

* ``overhead`` — the enabled monitor must be cheap: interleaved
  (monitor-off, monitor-on) driver-run pairs with telemetry on in both
  arms, GATED on the median makespan ratio ≤
  ``run.MAX_MONITOR_OVERHEAD`` (+ a small absolute slack — the
  denominators are fractions of a second on CI) with every pair's
  result bit-identical.
* ``disabled`` — the :class:`MonitorOptions` default leaves the
  platform untouched: no monitor object, zero bus taps, zero
  ``alert_*`` events, result bit-identical to a monitor-on run.  GATED.
* ``diagnosis`` — seeded fault-plan accuracy: clean runs over the
  4-node store must produce ZERO findings (``--chaos`` widens the seed
  sweep; the nightly zero-false-positive assertion), and a deterministic
  plan injecting a worker crash + node kill + latency spike must see
  every fired fault named in :meth:`PlatformMonitor.diagnose` output,
  bit-identically to clean.  The monitor HTML report and the alert
  history land in ``bench_out/`` (the CI artifacts).  GATED.
* ``critical_path`` — the per-job phase attribution must reconstruct
  the measured makespan: phase seconds sum within
  ``run.CRITICAL_PATH_TOLERANCE`` of the job makespan on BOTH the
  threaded and the simulated backend (median over repeats).  GATED.

The overhead ratio is the only wall-clock gate here and carries its own
absolute slack, per harness convention.
"""

from __future__ import annotations

import argparse
import os
import statistics
import sys
from typing import Dict, List

import numpy as np

from benchmarks.common import Row
from repro.core import subsample as ss
from repro.core.datastore import ReplicatedDataStore, ReplicationPolicy
from repro.data.synthetic import NetflixSpec, netflix_dataset
from repro.platform import FaultOptions, Platform, PlatformSpec
from repro.platform.faults import FaultEvent, FaultInjector, FaultPlan
from repro.platform.monitor import write_alerts_jsonl, write_monitor_report

# machine-readable results for BENCH_platform.json (populated by run())
STRUCTURED: Dict[str, dict] = {}

KNEE = 4 * 1024 * 4
WL = ss.NETFLIX_HIGH
OVERHEAD_PAIRS = 5
CRITICAL_PATH_REPEATS = 3
# clean-run seeds for the zero-false-positive sweep; nightly --chaos
# widens it (the seeds vary the subsampling draws, not the fault plan —
# there is no fault plan on the clean arm by construction)
CLEAN_SEEDS = (11, 13)
CLEAN_SEEDS_NIGHTLY = (11, 13, 17, 23, 29)
# deterministic fault plan for the diagnosis-accuracy gate.  The latency
# factor is deliberately large: FaultPlan.from_seed draws factors from
# U(2, 8), and a spike below the store's 3x degraded/outlier thresholds
# is undetectable by design — the naming gate needs a spike a correct
# monitor MUST see.  Measured fetch times carry ~2-3ms of timer slop on
# top of BASE_LAT under thread contention, so the factor keeps the
# spiked node well above 3x the peers' OBSERVED (not nominal) latency.
FAULT_PLAN = FaultPlan(events=(
    FaultEvent("worker_crash", target=1, at_claims=2),
    FaultEvent("node_kill", target=2, at_completions=6),
    FaultEvent("node_latency", target=0, at_completions=1, factor=12.0),
))
BASE_LAT = 2e-3
N_NODES = 4
# side artifacts land in the (git-ignored) bench_out/ directory; only
# BENCH_platform.json — the cross-PR metric record — stays at the root
OUT_DIR = "bench_out"
REPORT_PATH = os.path.join(OUT_DIR, "monitor_report.html")
ALERTS_PATH = os.path.join(OUT_DIR, "monitor_alerts.jsonl")


def _dataset():
    return netflix_dataset(NetflixSpec(n_movies=24, mean_ratings=1024))


def _spec(**kw) -> PlatformSpec:
    base = dict(platform="BTS", n_workers=3, backend="threaded",
                knee_bytes=KNEE, seed=11)
    base.update(kw)
    return PlatformSpec(**base)


def _store() -> ReplicatedDataStore:
    # bench_balance's 4-node store idiom; least_inflight keeps the
    # spiked node serving measurable fetches (no traffic shedding), so
    # the latency outlier stays observable to the monitor
    return ReplicatedDataStore(
        n_initial=N_NODES,
        policy=ReplicationPolicy(fetch_slo=BASE_LAT, window=10_000,
                                 max_replicas=N_NODES),
        latency=lambda nbytes: BASE_LAT,
        select="least_inflight")


def _results_equal(a: dict, b: dict) -> bool:
    return (set(a) == set(b)
            and all(np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
                    for k in a))


# ---------------------------------------------------------------------------
# overhead: interleaved monitor-off/on pairs, median makespan ratio
# ---------------------------------------------------------------------------


def _overhead_section(rows: List[Row], samples, months) -> None:
    ratios, off_s, on_s = [], [], []
    identical = True
    for _ in range(OVERHEAD_PAIRS):
        r_off = Platform(_spec(telemetry=True)).run(samples, months, WL)
        r_on = Platform(_spec(telemetry=True, monitor=True)).run(
            samples, months, WL)
        identical = identical and _results_equal(r_off.result, r_on.result)
        off_s.append(r_off.makespan)
        on_s.append(r_on.makespan)
        ratios.append(r_on.makespan / max(r_off.makespan, 1e-9))
    out = {
        "pairs": OVERHEAD_PAIRS,
        "median_ratio": statistics.median(ratios),
        "median_off_s": statistics.median(off_s),
        "median_on_s": statistics.median(on_s),
        "bit_identical": identical,
    }
    rows.append(("monitor.overhead.median_ratio", out["median_ratio"],
                 f"bit_identical={identical}"))
    rows.append(("monitor.overhead.median_on_s",
                 out["median_on_s"] * 1e6, "wall"))
    STRUCTURED["overhead"] = out


# ---------------------------------------------------------------------------
# disabled: MonitorOptions default ⇒ no taps, no alert events, identical
# ---------------------------------------------------------------------------


def _disabled_section(rows: List[Row], samples, months) -> None:
    p_off = Platform(_spec(telemetry=True))
    r_off = p_off.run(samples, months, WL)
    snap_off = p_off.telemetry.snapshot()
    alert_events = sum(
        snap_off["events_by_kind"].get(k, 0)
        for k in ("alert_raised", "alert_cleared"))
    alert_counters = (
        snap_off["metrics"]["counters"].get("alerts_raised", 0.0)
        + snap_off["metrics"]["counters"].get("alerts_cleared", 0.0))
    p_on = Platform(_spec(telemetry=True, monitor=True))
    r_on = p_on.run(samples, months, WL)
    out = {
        "monitor_absent": p_off.monitor is None,
        "taps": len(getattr(p_off.telemetry, "_taps", ())),
        "alert_events": int(alert_events + alert_counters),
        "bit_identical": _results_equal(r_off.result, r_on.result),
    }
    rows.append(("monitor.disabled.alert_events",
                 float(out["alert_events"]),
                 f"absent={out['monitor_absent']}_taps={out['taps']}_"
                 f"bit_identical={out['bit_identical']}"))
    STRUCTURED["disabled"] = out


# ---------------------------------------------------------------------------
# diagnosis: zero findings on clean runs, every injected fault named
# ---------------------------------------------------------------------------


def _fault_named(fired: FaultEvent, findings: List[dict]) -> bool:
    """True when ``findings`` names the fired fault: a killed node must
    surface as a DOWN degraded_node, a latency spike as a degraded_node
    on that node, a worker crash as worker_churn on that worker."""
    kind, target = fired.kind, fired.target
    if kind == "worker_crash":
        return any(f["kind"] == "worker_churn" and f.get("worker") == target
                   for f in findings)
    if kind == "node_kill":
        return any(f["kind"] == "degraded_node" and f.get("node") == target
                   and f.get("state") == "down" for f in findings)
    if kind == "node_latency":
        return any(f["kind"] == "degraded_node" and f.get("node") == target
                   for f in findings)
    return True


def _diagnosis_section(rows: List[Row], samples, months,
                       chaos: bool) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    spec_kw = dict(telemetry=True, monitor=True,
                   faults=FaultOptions(lease_seconds=0.5))
    # clean sweep: any finding on a fault-free run is a false positive
    seeds = CLEAN_SEEDS_NIGHTLY if chaos else CLEAN_SEEDS
    clean_counts: Dict[str, int] = {}
    clean_result = None
    for seed in seeds:
        p = Platform(_spec(seed=seed, **spec_kw), datastore=_store())
        rep = p.run(samples, months, WL)
        findings = p.monitor_snapshot()["findings"]
        clean_counts[str(seed)] = len(findings)
        if seed == _spec().seed:
            clean_result = rep.result
        rows.append((f"monitor.diagnosis.clean.seed{seed}.findings",
                     float(len(findings)), "false_positives"))
    if clean_result is None:
        p = Platform(_spec(**spec_kw), datastore=_store())
        clean_result = p.run(samples, months, WL).result

    # fault arm: the deterministic plan, same spec/seed as the clean run
    injector = FaultInjector(FAULT_PLAN)
    p = Platform(_spec(**spec_kw), datastore=_store(),
                 fault_injector=injector)
    rep = p.run(samples, months, WL)
    snap = p.monitor_snapshot()
    findings = snap["findings"]
    named = {f"{e.kind}:{e.target}": _fault_named(e, findings)
             for e in injector.fired}
    write_monitor_report(p.monitor, REPORT_PATH,
                         title="bench_monitor seeded faults")
    alert_lines = write_alerts_jsonl(p.monitor, ALERTS_PATH)

    out = {
        "clean_seeds": clean_counts,
        "all_clean_zero": all(c == 0 for c in clean_counts.values()),
        "fault": {
            "fired": len(injector.fired),
            "planned": len(FAULT_PLAN.events),
            "named": named,
            "all_named": (len(injector.fired) == len(FAULT_PLAN.events)
                          and all(named.values())),
            "findings": [{"kind": f["kind"], "severity": f["severity"],
                          "summary": f["summary"]} for f in findings],
            "bit_identical": _results_equal(clean_result, rep.result),
            "alerts_raised": len(snap["alerts"]["history"]),
        },
        "report_path": REPORT_PATH,
        "alerts_path": ALERTS_PATH,
        "alert_lines": alert_lines,
    }
    rows.append(("monitor.diagnosis.fault.findings", float(len(findings)),
                 f"all_named={out['fault']['all_named']}_"
                 f"bit_identical={out['fault']['bit_identical']}"))
    rows.append(("monitor.diagnosis.fault.alerts", float(alert_lines),
                 "history"))
    STRUCTURED["diagnosis"] = out


# ---------------------------------------------------------------------------
# critical path: phase seconds reconstruct the makespan on both backends
# ---------------------------------------------------------------------------


def _critical_path_section(rows: List[Row], samples, months) -> None:
    out: Dict[str, dict] = {}
    for backend in ("threaded", "simulated"):
        ratios = []
        for _ in range(CRITICAL_PATH_REPEATS):
            p = Platform(_spec(backend=backend, telemetry=True,
                               monitor=True))
            p.run(samples, months, WL)
            cp = p.monitor_snapshot()["critical_path"]
            (rec,) = cp.values()
            ratios.append(rec["phase_sum"] / max(rec["makespan"], 1e-9))
        out[backend] = {
            "repeats": CRITICAL_PATH_REPEATS,
            "ratios": ratios,
            "median_ratio": statistics.median(ratios),
            "tasks_settled": rec["tasks_settled"],
        }
        rows.append((f"monitor.critical_path.{backend}.ratio",
                     out[backend]["median_ratio"],
                     f"tasks={rec['tasks_settled']}"))
    STRUCTURED["critical_path"] = out


def run(smoke: bool = False, chaos: bool = False) -> List[Row]:
    del smoke          # sizes fixed: the diagnosis/identity gates need them
    samples, months = _dataset()
    rows: List[Row] = []
    _overhead_section(rows, samples, months)
    _disabled_section(rows, samples, months)
    _diagnosis_section(rows, samples, months, chaos)
    _critical_path_section(rows, samples, months)
    return rows


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true")
    parser.add_argument("--chaos", action="store_true",
                        help="widen the clean-run seed sweep for the "
                        "zero-false-positive assertion (nightly CI)")
    args = parser.parse_args(argv)
    print("name,us_per_call,derived")
    for name, us, derived in run(smoke=args.smoke, chaos=args.chaos):
        print(f"{name},{us:.3f},{derived}")
    # standalone runs apply the same structured gates as the run.py
    # harness (bounded overhead, disabled-is-absent, diagnosis accuracy,
    # critical-path reconstruction)
    from benchmarks.run import _check_monitor_regression
    failures = _check_monitor_regression(STRUCTURED)
    for msg in failures:
        print(f"# FAIL: {msg}", file=sys.stderr)
    return 2 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
